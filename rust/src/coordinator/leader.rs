//! Leader: receives the M unidirectional draw streams and maintains the
//! online combination state (paper section 4's online variant).

use std::sync::mpsc::Receiver;

use crate::combine::{
    CombineMethod, CombineTuning, OnlineCombiner,
    DEFAULT_ANNEAL_CACHE_BUDGET,
};
use crate::coordinator::transport::DrawChunk;
use crate::coordinator::worker::DrawMsg;
use crate::error::{Error, Result};
use crate::kernel::CombineKernelKind;
use crate::types::{DrawStoreConfig, DrawStoreStats, SampleMatrix};

/// One unit of leader-bound traffic: a single draw (JSON wire /
/// native thread mode) or a batched binary chunk carrying many rows.
/// Chunks are moved, never copied, so the leader ingests the same
/// buffer the transport decoded into.
#[derive(Debug, Clone)]
pub enum LeaderMsg {
    Draw(DrawMsg),
    Chunk(DrawChunk),
    /// A scheduler thread observed machine `machine`'s stream fail and
    /// is about to re-dispatch it: discard every row received so far.
    /// Each machine has exactly one live sender at a time, so on the
    /// leader's FIFO channel a Reset always lands after the failed
    /// attempt's partial traffic and before the retry's.
    Reset { machine: usize },
}

/// Leader-side stream consumer.
pub struct Leader {
    combiner: OnlineCombiner,
    finished: Vec<bool>,
    /// Combine-stage thread count for [`Leader::draws`] (`0` = all
    /// cores). Output is byte-identical at any count, so this only
    /// changes wall-clock.
    combine_threads: usize,
    /// Annealed-factorization-cache budget in bytes for
    /// [`Leader::draws`]; byte-identical output at any value.
    combine_cache_budget: usize,
    /// Compute-kernel backend for [`Leader::draws`]'s dense combine
    /// ops; CPU backends are bit-identical.
    combine_kernel: CombineKernelKind,
    /// Max worker-local elapsed time seen so far (cluster clock).
    pub max_elapsed: f64,
    /// Scalars received (d per draw) — the paper's O(dTM) communication.
    pub scalars_received: usize,
}

impl Leader {
    pub fn new(machines: usize, dim: usize) -> Self {
        Leader::with_store_config(machines, dim, DrawStoreConfig::default())
    }

    /// Leader whose per-machine draw plane uses an explicit
    /// [`DrawStoreConfig`] (chunk size + spill budget) — the pipeline
    /// wires the `chunk_rows` / `draw_spill_budget_mb` config through
    /// here. Retained draws are byte-identical at any configuration.
    pub fn with_store_config(
        machines: usize,
        dim: usize,
        store_cfg: DrawStoreConfig,
    ) -> Self {
        Leader {
            combiner: OnlineCombiner::with_store_config(
                machines, dim, store_cfg,
            ),
            finished: vec![false; machines],
            combine_threads: 1,
            combine_cache_budget: DEFAULT_ANNEAL_CACHE_BUDGET,
            combine_kernel: CombineKernelKind::default(),
            max_elapsed: 0.0,
            scalars_received: 0,
        }
    }

    /// Aggregate draw-plane memory accounting across every machine's
    /// store (see [`OnlineCombiner::draw_stats`]) — the pipeline
    /// summary's peak/spilled bytes source.
    pub fn draw_stats(&self) -> DrawStoreStats {
        self.combiner.draw_stats()
    }

    /// Set the combine-stage thread count used by [`Leader::draws`]
    /// (`0` = all cores). The pipeline wires its `combine_threads`
    /// config through here so mid-stream combination requests run on
    /// the same parallel runtime as the final combine.
    pub fn set_combine_threads(&mut self, threads: usize) {
        self.combine_threads = threads;
    }

    /// Set the annealed-factorization-cache budget (bytes) used by
    /// [`Leader::draws`] — the pipeline wires `combine_cache_budget_mb`
    /// through here. A tiny budget falls back to in-place
    /// recomputation with bit-identical output.
    pub fn set_combine_cache_budget(&mut self, bytes: usize) {
        self.combine_cache_budget = bytes;
    }

    /// Select the compute-kernel backend ([`crate::kernel`]) used by
    /// [`Leader::draws`] — the pipeline wires `combine_backend`
    /// through here. CPU backends are bit-identical; an unavailable
    /// backend (e.g. `device` offline) surfaces as a structured error
    /// from `draws`, never a panic.
    pub fn set_combine_kernel(&mut self, kernel: CombineKernelKind) {
        self.combine_kernel = kernel;
    }

    /// Ingest one message.
    pub fn ingest(&mut self, msg: &DrawMsg) -> Result<()> {
        self.combiner.push(msg.machine, &msg.theta)?;
        self.scalars_received += msg.theta.len();
        if msg.elapsed > self.max_elapsed {
            self.max_elapsed = msg.elapsed;
        }
        if msg.last {
            self.finished[msg.machine] = true;
        }
        Ok(())
    }

    /// Ingest one batched binary chunk: the whole payload lands in the
    /// machine's draw store as one bulk copy
    /// ([`OnlineCombiner::push_rows`]) — no per-draw `DrawMsg`
    /// materialization, no per-row push loop. Validation runs before
    /// anything lands, so a bad chunk leaves no partial rows behind.
    pub fn ingest_chunk(&mut self, chunk: &DrawChunk) -> Result<()> {
        if chunk.dim == 0 || chunk.thetas.len() % chunk.dim != 0 {
            return Err(Error::Runtime(format!(
                "draw chunk from machine {} has ragged payload ({} scalars, dim {})",
                chunk.machine,
                chunk.thetas.len(),
                chunk.dim
            )));
        }
        if chunk.dim != self.combiner.dim() {
            return Err(Error::Shape(format!(
                "draw dim {} != {}",
                chunk.dim,
                self.combiner.dim()
            )));
        }
        if !chunk.thetas.is_empty() {
            self.combiner.push_rows(chunk.machine, &chunk.thetas)?;
        }
        self.scalars_received += chunk.thetas.len();
        for &e in &chunk.elapsed {
            if e > self.max_elapsed {
                self.max_elapsed = e;
            }
        }
        if chunk.last {
            if chunk.machine >= self.finished.len() {
                return Err(Error::Runtime(format!(
                    "draw chunk from unknown machine {}",
                    chunk.machine
                )));
            }
            self.finished[chunk.machine] = true;
        }
        Ok(())
    }

    /// Drain a receiver until every worker has sent its final message
    /// (or the channel closes).
    pub fn drain(&mut self, rx: &Receiver<DrawMsg>) -> Result<()> {
        for msg in rx.iter() {
            self.ingest(&msg)?;
            if self.all_finished() {
                break;
            }
        }
        Ok(())
    }

    /// Drain a mixed draw/chunk stream ([`LeaderMsg`]) until every
    /// worker has sent its final message (or the channel closes).
    /// Driver-agnostic: the thread-per-endpoint scheduler and the
    /// `poll(2)` reactor ([`crate::coordinator::reactor`]) feed the
    /// same channel, so the leader cannot tell the drivers apart —
    /// one half of the `--io-driver` byte-identity contract.
    pub fn drain_stream(&mut self, rx: &Receiver<LeaderMsg>) -> Result<()> {
        for msg in rx.iter() {
            self.ingest_msg(msg)?;
            if self.all_finished() {
                break;
            }
        }
        Ok(())
    }

    /// Drain a mixed stream until the channel closes, with no
    /// `all_finished` early exit. The retry scheduler needs this
    /// variant: under `--failure-policy retry` a machine can finish,
    /// then a *different* machine's failure arrives, so "all finished"
    /// is not a stable condition until every sender is gone — exiting
    /// early would strand Reset messages in the channel and ingest a
    /// retried stream on top of the failed prefix. Both retry
    /// schedulers (threads and reactor) drain through here.
    pub fn drain_stream_all(
        &mut self,
        rx: &Receiver<LeaderMsg>,
    ) -> Result<()> {
        for msg in rx.iter() {
            self.ingest_msg(msg)?;
        }
        Ok(())
    }

    /// Dispatch one [`LeaderMsg`] to the right ingest path.
    pub fn ingest_msg(&mut self, msg: LeaderMsg) -> Result<()> {
        match msg {
            LeaderMsg::Draw(d) => self.ingest(&d),
            LeaderMsg::Chunk(c) => self.ingest_chunk(&c),
            LeaderMsg::Reset { machine } => self.reset_machine(machine),
        }
    }

    /// Discard everything received from `machine` (draw rows, moments,
    /// scalar accounting, completion flag) ahead of a shard retry.
    /// Because worker RNG streams are endpoint-independent
    /// (`root.split(m)`), the re-dispatched shard regenerates the
    /// discarded prefix bit-identically — this is what keeps retried
    /// runs byte-identical to unfaulted ones.
    pub fn reset_machine(&mut self, machine: usize) -> Result<()> {
        let dropped = self.combiner.reset_machine(machine)?;
        self.scalars_received -= dropped * self.combiner.dim();
        self.finished[machine] = false;
        Ok(())
    }

    pub fn all_finished(&self) -> bool {
        self.finished.iter().all(|&f| f)
    }

    pub fn combiner(&self) -> &OnlineCombiner {
        &self.combiner
    }

    /// Current full-posterior draws by any method over what has streamed
    /// in so far, on the configured combine-stage thread pool.
    pub fn draws(
        &self,
        method: CombineMethod,
        t_out: usize,
        seed: u64,
    ) -> Result<SampleMatrix> {
        self.combiner.combined_draws_with(
            method,
            t_out,
            seed,
            &CombineTuning {
                threads: self.combine_threads,
                cache_budget_bytes: self.combine_cache_budget,
                kernel: self.combine_kernel,
            },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn msg(machine: usize, v: f64, last: bool) -> DrawMsg {
        DrawMsg { machine, theta: vec![v], elapsed: v.abs(), last }
    }

    #[test]
    fn threaded_draws_are_thread_count_invariant() {
        use crate::combine::CombineMethod;
        let mut rng = crate::rng::Pcg64::seed_from(3);
        let mut serial = Leader::new(2, 1);
        let mut threaded = Leader::new(2, 1);
        threaded.set_combine_threads(4);
        for i in 0..300 {
            for m in 0..2 {
                let d = msg(m, rng.normal() + m as f64, i == 299);
                serial.ingest(&d).unwrap();
                threaded.ingest(&d).unwrap();
            }
        }
        let a = serial.draws(CombineMethod::Nonparametric, 500, 5).unwrap();
        let b =
            threaded.draws(CombineMethod::Nonparametric, 500, 5).unwrap();
        assert_eq!(a.as_slice(), b.as_slice());
    }

    #[test]
    fn tracks_completion_and_telemetry() {
        let mut leader = Leader::new(2, 1);
        leader.ingest(&msg(0, 1.0, false)).unwrap();
        leader.ingest(&msg(1, 2.0, false)).unwrap();
        assert!(!leader.all_finished());
        leader.ingest(&msg(0, 3.0, true)).unwrap();
        leader.ingest(&msg(1, 0.5, true)).unwrap();
        assert!(leader.all_finished());
        assert_eq!(leader.scalars_received, 4);
        assert!((leader.max_elapsed - 3.0).abs() < 1e-12);
    }

    #[test]
    fn drain_consumes_channel() {
        use std::sync::mpsc::channel;
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(msg(0, i as f64, i == 9)).unwrap();
        }
        drop(tx);
        let mut leader = Leader::new(1, 1);
        leader.drain(&rx).unwrap();
        assert!(leader.all_finished());
        assert_eq!(leader.combiner().total_received(), 10);
    }

    #[test]
    fn rejects_bad_machine() {
        let mut leader = Leader::new(1, 1);
        assert!(leader.ingest(&msg(5, 0.0, false)).is_err());
    }

    #[test]
    fn chunk_ingest_matches_per_draw_ingest() {
        let mut rng = crate::rng::Pcg64::seed_from(11);
        let mut per_draw = Leader::new(2, 3);
        let mut chunked = Leader::new(2, 3);
        for m in 0..2usize {
            let mut thetas = Vec::new();
            let mut elapsed = Vec::new();
            for i in 0..20 {
                let theta: Vec<f64> =
                    (0..3).map(|_| rng.normal() + m as f64).collect();
                let e = 0.1 * (i as f64 + 1.0);
                per_draw
                    .ingest(&DrawMsg {
                        machine: m,
                        theta: theta.clone(),
                        elapsed: e,
                        last: i == 19,
                    })
                    .unwrap();
                thetas.extend_from_slice(&theta);
                elapsed.push(e);
            }
            chunked
                .ingest_chunk(&DrawChunk {
                    machine: m,
                    dim: 3,
                    thetas,
                    elapsed,
                    last: true,
                })
                .unwrap();
        }
        assert!(per_draw.all_finished() && chunked.all_finished());
        assert_eq!(per_draw.scalars_received, chunked.scalars_received);
        assert_eq!(per_draw.max_elapsed, chunked.max_elapsed);
        let a = per_draw.draws(CombineMethod::Parametric, 64, 7).unwrap();
        let b = chunked.draws(CombineMethod::Parametric, 64, 7).unwrap();
        assert_eq!(a.as_slice(), b.as_slice());
    }

    #[test]
    fn chunk_ingest_rejects_ragged_and_unknown_machine() {
        let mut leader = Leader::new(1, 2);
        let ragged = DrawChunk {
            machine: 0,
            dim: 2,
            thetas: vec![1.0, 2.0, 3.0],
            elapsed: vec![0.1],
            last: false,
        };
        assert!(leader.ingest_chunk(&ragged).is_err());
        let stray = DrawChunk {
            machine: 4,
            dim: 2,
            thetas: vec![],
            elapsed: vec![],
            last: true,
        };
        assert!(leader.ingest_chunk(&stray).is_err());
    }

    /// A chunk that fails validation lands nothing: rows from the
    /// preceding good chunk are retained, none of the bad chunk's —
    /// the no-partial-rows half of the fail-fast contract.
    #[test]
    fn failed_chunk_leaves_no_partial_rows() {
        let mut leader = Leader::new(1, 2);
        leader
            .ingest_chunk(&DrawChunk {
                machine: 0,
                dim: 2,
                thetas: vec![1.0, 2.0, 3.0, 4.0],
                elapsed: vec![0.1, 0.2],
                last: false,
            })
            .unwrap();
        let ragged = DrawChunk {
            machine: 0,
            dim: 2,
            thetas: vec![5.0, 6.0, 7.0],
            elapsed: vec![0.3],
            last: false,
        };
        assert!(leader.ingest_chunk(&ragged).is_err());
        let wrong_dim = DrawChunk {
            machine: 0,
            dim: 3,
            thetas: vec![5.0, 6.0, 7.0],
            elapsed: vec![0.3],
            last: false,
        };
        let err = leader.ingest_chunk(&wrong_dim).unwrap_err();
        assert!(err.to_string().contains("draw dim 3 != 2"), "{err}");
        assert_eq!(leader.combiner().total_received(), 2);
        assert_eq!(leader.scalars_received, 4);
    }

    /// A spill-configured leader reports spilled bytes and emits draws
    /// byte-identical to a dense leader fed the same stream.
    #[test]
    fn spill_configured_leader_matches_dense() {
        let cfg = DrawStoreConfig {
            chunk_rows: 7,
            spill_budget_bytes: Some(0),
        };
        let mut rng = crate::rng::Pcg64::seed_from(23);
        let mut dense = Leader::new(2, 1);
        let mut spill = Leader::with_store_config(2, 1, cfg);
        for i in 0..200 {
            for m in 0..2 {
                let d = msg(m, rng.normal() + m as f64, i == 199);
                dense.ingest(&d).unwrap();
                spill.ingest(&d).unwrap();
            }
        }
        let stats = spill.draw_stats();
        assert!(stats.spilled_bytes > 0);
        assert!(stats.peak_resident_bytes > 0);
        assert_eq!(dense.draw_stats().spilled_bytes, 0);
        let a =
            dense.draws(CombineMethod::Semiparametric, 300, 5).unwrap();
        let b =
            spill.draws(CombineMethod::Semiparametric, 300, 5).unwrap();
        assert_eq!(a.as_slice(), b.as_slice());
    }

    /// An in-band Reset discards the failed attempt's partial rows and
    /// completion flag; replaying the full stream afterwards leaves the
    /// leader indistinguishable from one that never saw the failure.
    #[test]
    fn reset_then_replay_matches_unfaulted_leader() {
        use std::sync::mpsc::channel;
        let stream: Vec<DrawMsg> =
            (0..8).map(|i| msg(0, i as f64, i == 7)).collect();
        let mut clean = Leader::new(1, 1);
        for d in &stream {
            clean.ingest(d).unwrap();
        }
        let (tx, rx) = channel();
        // Failed attempt: 5 draws land (one even flagged last), then
        // the scheduler resets and the retry replays from the top.
        for d in &stream[..5] {
            tx.send(LeaderMsg::Draw(d.clone())).unwrap();
        }
        tx.send(LeaderMsg::Reset { machine: 0 }).unwrap();
        for d in &stream {
            tx.send(LeaderMsg::Draw(d.clone())).unwrap();
        }
        drop(tx);
        let mut retried = Leader::new(1, 1);
        retried.drain_stream_all(&rx).unwrap();
        assert!(retried.all_finished());
        assert_eq!(
            retried.combiner().total_received(),
            clean.combiner().total_received()
        );
        assert_eq!(retried.scalars_received, clean.scalars_received);
        let a = clean.draws(CombineMethod::Parametric, 32, 7).unwrap();
        let b = retried.draws(CombineMethod::Parametric, 32, 7).unwrap();
        assert_eq!(a.as_slice(), b.as_slice());
        assert!(retried.reset_machine(3).is_err());
    }

    /// `drain_stream` (fail-fast path) still early-exits on completion;
    /// a Reset mid-stream un-finishes the machine so the early exit
    /// cannot fire between a failure and its retry.
    #[test]
    fn reset_unfinishes_a_completed_machine() {
        let mut leader = Leader::new(1, 1);
        leader.ingest(&msg(0, 1.0, true)).unwrap();
        assert!(leader.all_finished());
        leader.reset_machine(0).unwrap();
        assert!(!leader.all_finished());
        assert_eq!(leader.scalars_received, 0);
        assert_eq!(leader.combiner().total_received(), 0);
    }

    #[test]
    fn drain_stream_consumes_mixed_traffic() {
        use std::sync::mpsc::channel;
        let (tx, rx) = channel();
        for i in 0..4 {
            tx.send(LeaderMsg::Draw(msg(0, i as f64, false))).unwrap();
        }
        tx.send(LeaderMsg::Chunk(DrawChunk {
            machine: 0,
            dim: 1,
            thetas: vec![4.0, 5.0],
            elapsed: vec![4.0, 5.0],
            last: true,
        }))
        .unwrap();
        drop(tx);
        let mut leader = Leader::new(1, 1);
        leader.drain_stream(&rx).unwrap();
        assert!(leader.all_finished());
        assert_eq!(leader.combiner().total_received(), 6);
        assert_eq!(leader.scalars_received, 6);
        assert!((leader.max_elapsed - 5.0).abs() < 1e-12);
    }
}
