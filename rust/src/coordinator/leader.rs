//! Leader: receives the M unidirectional draw streams and maintains the
//! online combination state (paper section 4's online variant).

use std::sync::mpsc::Receiver;

use crate::combine::{
    CombineMethod, CombineTuning, OnlineCombiner,
    DEFAULT_ANNEAL_CACHE_BUDGET,
};
use crate::coordinator::worker::DrawMsg;
use crate::error::Result;
use crate::kernel::CombineKernelKind;
use crate::types::SampleMatrix;

/// Leader-side stream consumer.
pub struct Leader {
    combiner: OnlineCombiner,
    finished: Vec<bool>,
    /// Combine-stage thread count for [`Leader::draws`] (`0` = all
    /// cores). Output is byte-identical at any count, so this only
    /// changes wall-clock.
    combine_threads: usize,
    /// Annealed-factorization-cache budget in bytes for
    /// [`Leader::draws`]; byte-identical output at any value.
    combine_cache_budget: usize,
    /// Compute-kernel backend for [`Leader::draws`]'s dense combine
    /// ops; CPU backends are bit-identical.
    combine_kernel: CombineKernelKind,
    /// Max worker-local elapsed time seen so far (cluster clock).
    pub max_elapsed: f64,
    /// Scalars received (d per draw) — the paper's O(dTM) communication.
    pub scalars_received: usize,
}

impl Leader {
    pub fn new(machines: usize, dim: usize) -> Self {
        Leader {
            combiner: OnlineCombiner::new(machines, dim),
            finished: vec![false; machines],
            combine_threads: 1,
            combine_cache_budget: DEFAULT_ANNEAL_CACHE_BUDGET,
            combine_kernel: CombineKernelKind::default(),
            max_elapsed: 0.0,
            scalars_received: 0,
        }
    }

    /// Set the combine-stage thread count used by [`Leader::draws`]
    /// (`0` = all cores). The pipeline wires its `combine_threads`
    /// config through here so mid-stream combination requests run on
    /// the same parallel runtime as the final combine.
    pub fn set_combine_threads(&mut self, threads: usize) {
        self.combine_threads = threads;
    }

    /// Set the annealed-factorization-cache budget (bytes) used by
    /// [`Leader::draws`] — the pipeline wires `combine_cache_budget_mb`
    /// through here. A tiny budget falls back to in-place
    /// recomputation with bit-identical output.
    pub fn set_combine_cache_budget(&mut self, bytes: usize) {
        self.combine_cache_budget = bytes;
    }

    /// Select the compute-kernel backend ([`crate::kernel`]) used by
    /// [`Leader::draws`] — the pipeline wires `combine_backend`
    /// through here. CPU backends are bit-identical; an unavailable
    /// backend (e.g. `device` offline) surfaces as a structured error
    /// from `draws`, never a panic.
    pub fn set_combine_kernel(&mut self, kernel: CombineKernelKind) {
        self.combine_kernel = kernel;
    }

    /// Ingest one message.
    pub fn ingest(&mut self, msg: &DrawMsg) -> Result<()> {
        self.combiner.push(msg.machine, &msg.theta)?;
        self.scalars_received += msg.theta.len();
        if msg.elapsed > self.max_elapsed {
            self.max_elapsed = msg.elapsed;
        }
        if msg.last {
            self.finished[msg.machine] = true;
        }
        Ok(())
    }

    /// Drain a receiver until every worker has sent its final message
    /// (or the channel closes).
    pub fn drain(&mut self, rx: &Receiver<DrawMsg>) -> Result<()> {
        for msg in rx.iter() {
            self.ingest(&msg)?;
            if self.all_finished() {
                break;
            }
        }
        Ok(())
    }

    pub fn all_finished(&self) -> bool {
        self.finished.iter().all(|&f| f)
    }

    pub fn combiner(&self) -> &OnlineCombiner {
        &self.combiner
    }

    /// Current full-posterior draws by any method over what has streamed
    /// in so far, on the configured combine-stage thread pool.
    pub fn draws(
        &self,
        method: CombineMethod,
        t_out: usize,
        seed: u64,
    ) -> Result<SampleMatrix> {
        self.combiner.combined_draws_with(
            method,
            t_out,
            seed,
            &CombineTuning {
                threads: self.combine_threads,
                cache_budget_bytes: self.combine_cache_budget,
                kernel: self.combine_kernel,
            },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn msg(machine: usize, v: f64, last: bool) -> DrawMsg {
        DrawMsg { machine, theta: vec![v], elapsed: v.abs(), last }
    }

    #[test]
    fn threaded_draws_are_thread_count_invariant() {
        use crate::combine::CombineMethod;
        let mut rng = crate::rng::Pcg64::seed_from(3);
        let mut serial = Leader::new(2, 1);
        let mut threaded = Leader::new(2, 1);
        threaded.set_combine_threads(4);
        for i in 0..300 {
            for m in 0..2 {
                let d = msg(m, rng.normal() + m as f64, i == 299);
                serial.ingest(&d).unwrap();
                threaded.ingest(&d).unwrap();
            }
        }
        let a = serial.draws(CombineMethod::Nonparametric, 500, 5).unwrap();
        let b =
            threaded.draws(CombineMethod::Nonparametric, 500, 5).unwrap();
        assert_eq!(a.as_slice(), b.as_slice());
    }

    #[test]
    fn tracks_completion_and_telemetry() {
        let mut leader = Leader::new(2, 1);
        leader.ingest(&msg(0, 1.0, false)).unwrap();
        leader.ingest(&msg(1, 2.0, false)).unwrap();
        assert!(!leader.all_finished());
        leader.ingest(&msg(0, 3.0, true)).unwrap();
        leader.ingest(&msg(1, 0.5, true)).unwrap();
        assert!(leader.all_finished());
        assert_eq!(leader.scalars_received, 4);
        assert!((leader.max_elapsed - 3.0).abs() < 1e-12);
    }

    #[test]
    fn drain_consumes_channel() {
        use std::sync::mpsc::channel;
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(msg(0, i as f64, i == 9)).unwrap();
        }
        drop(tx);
        let mut leader = Leader::new(1, 1);
        leader.drain(&rx).unwrap();
        assert!(leader.all_finished());
        assert_eq!(leader.combiner().total_received(), 10);
    }

    #[test]
    fn rejects_bad_machine() {
        let mut leader = Leader::new(1, 1);
        assert!(leader.ingest(&msg(5, 0.0, false)).is_err());
    }
}
