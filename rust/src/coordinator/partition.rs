//! Data partitioning across machines (paper step 1: "arbitrarily
//! partition data onto multiple machines").

use crate::error::{Error, Result};
use crate::rng::Pcg64;

/// Partitioning strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Partitioner {
    /// Contiguous blocks (machine m gets rows [m·n/M, (m+1)·n/M)).
    Contiguous,
    /// Uniformly random assignment (the paper's i.i.d. setting makes
    /// this equivalent in distribution to contiguous, but it guards
    /// against ordered datasets).
    Random,
    /// Round-robin (deterministic, balanced to within one row).
    RoundRobin,
}

impl Partitioner {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "contiguous" => Ok(Partitioner::Contiguous),
            "random" => Ok(Partitioner::Random),
            "round_robin" => Ok(Partitioner::RoundRobin),
            other => Err(Error::Config(format!("unknown partitioner '{other}'"))),
        }
    }

    /// Split `0..n` into `m` shards. Every index appears exactly once;
    /// shard sizes differ by at most 1.
    pub fn split(&self, n: usize, m: usize, seed: u64) -> Result<Vec<Vec<usize>>> {
        if m == 0 {
            return Err(Error::Config("machines must be > 0".into()));
        }
        if n < m {
            return Err(Error::Config(format!(
                "cannot split {n} observations over {m} machines"
            )));
        }
        let mut shards: Vec<Vec<usize>> = match self {
            Partitioner::Contiguous => {
                let mut out = Vec::with_capacity(m);
                let base = n / m;
                let extra = n % m;
                let mut start = 0;
                for i in 0..m {
                    let len = base + usize::from(i < extra);
                    out.push((start..start + len).collect());
                    start += len;
                }
                out
            }
            Partitioner::Random => {
                let mut rng = Pcg64::seed_from(seed);
                let perm = rng.permutation(n);
                let mut out = vec![Vec::with_capacity(n / m + 1); m];
                for (i, idx) in perm.into_iter().enumerate() {
                    out[i % m].push(idx);
                }
                out
            }
            Partitioner::RoundRobin => {
                let mut out = vec![Vec::with_capacity(n / m + 1); m];
                for i in 0..n {
                    out[i % m].push(i);
                }
                out
            }
        };
        for s in shards.iter_mut() {
            s.sort_unstable();
        }
        Ok(shards)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_is_partition(shards: &[Vec<usize>], n: usize) {
        let mut seen = vec![false; n];
        for s in shards {
            for &i in s {
                assert!(!seen[i], "index {i} duplicated");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&b| b), "not all indices covered");
    }

    #[test]
    fn all_strategies_produce_partitions() {
        for p in [
            Partitioner::Contiguous,
            Partitioner::Random,
            Partitioner::RoundRobin,
        ] {
            for (n, m) in [(100, 10), (101, 10), (7, 7), (1000, 3)] {
                let shards = p.split(n, m, 42).unwrap();
                assert_eq!(shards.len(), m);
                assert_is_partition(&shards, n);
                let max = shards.iter().map(Vec::len).max().unwrap();
                let min = shards.iter().map(Vec::len).min().unwrap();
                assert!(max - min <= 1, "{p:?} imbalanced: {min}..{max}");
            }
        }
    }

    #[test]
    fn errors_on_degenerate_input() {
        assert!(Partitioner::Contiguous.split(10, 0, 0).is_err());
        assert!(Partitioner::Contiguous.split(3, 10, 0).is_err());
    }

    #[test]
    fn random_is_seed_deterministic() {
        let a = Partitioner::Random.split(50, 5, 7).unwrap();
        let b = Partitioner::Random.split(50, 5, 7).unwrap();
        let c = Partitioner::Random.split(50, 5, 8).unwrap();
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn parse_roundtrip() {
        assert_eq!(
            Partitioner::parse("contiguous").unwrap(),
            Partitioner::Contiguous
        );
        assert!(Partitioner::parse("nope").is_err());
    }
}
