//! Event-driven leader I/O: a hand-rolled `poll(2)` reactor that
//! multiplexes every worker socket on one thread (or a small fixed
//! pool, `--reactor-threads`), replacing thread-per-endpoint.
//!
//! The paper's workers need essentially no communication until the
//! combination stage, so the leader's job is pure I/O fan-in — which
//! a blocking thread per endpoint over-provisions by W threads and the
//! retry scheduler's 10 ms sleep-poll. Here each connection is a small
//! state machine: a reused receive buffer feeds the existing
//! [`FrameReader`] grammar incrementally (the reactor re-parses off an
//! in-memory slice, so the wire protocol is untouched), writes
//! (manifest frame, optional inline shard) go through a nonblocking
//! send queue with partial-write resume, and heartbeat/liveness
//! deadlines are per-connection entries folded into the poll timeout
//! instead of per-read `set_read_timeout` calls. Dispatch, requeue,
//! backoff and quarantine are re-driven off reactor events (readable,
//! frame complete, deadline expired, endpoint free) with the *same*
//! constants, attempt-log format, and Reset-before-requeue ordering as
//! the threads driver — so retained draws stay byte-identical: machine
//! m's RNG stream is `root.split(m)`, a function of the manifest, and
//! the reactor only changes *when* bytes arrive, never *what* lands.
//!
//! No new dependencies: the `poll(2)`/`pipe(2)`/`fcntl(2)` bindings
//! are bare `extern "C"` declarations in the same idiom as the
//! hand-rolled `mmap` in [`crate::data::io`].
//!
//! One reactor instance serves one pipeline run. Under the leader
//! daemon ([`crate::coordinator::server`]) each concurrent job that
//! selects `--io-driver reactor` gets its own instance — reactors
//! share no state, so multi-job concurrency composes with event-driven
//! I/O without a shared event loop arbitrating between jobs.

use std::collections::VecDeque;
use std::io::{Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::Sender;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::config::FailurePolicy;
use crate::coordinator::pipeline::{
    QUARANTINE_AFTER, RETRY_BACKOFF_BASE_MS, RETRY_BACKOFF_CAP_MS,
};
use crate::coordinator::transport::{
    write_frame_bytes, FrameReader, WireMsg, WorkerManifest, WorkerSummary,
    LIVENESS_EXPIRED_MARKER,
};
use crate::coordinator::LeaderMsg;
use crate::error::{Error, FrameError, Result};
use crate::types::{SampleMatrix, SubposteriorSamples};

/// Minimal `poll(2)` / `pipe(2)` / `fcntl(2)` bindings — no libc crate
/// (the repo is dependency-free by design), just the syscall wrappers
/// every unix libc exports with these C signatures. Public so the
/// `micro_hotpath` bench can drive the same poll loop it measures.
pub mod sys {
    use std::os::unix::io::RawFd;

    // POSIX poll event bits, identical on linux and the BSDs
    // (incl. macOS).
    pub const POLLIN: i16 = 0x001;
    pub const POLLOUT: i16 = 0x004;
    pub const POLLERR: i16 = 0x008;
    pub const POLLHUP: i16 = 0x010;
    pub const POLLNVAL: i16 = 0x020;

    const F_GETFL: i32 = 3;
    const F_SETFL: i32 = 4;
    #[cfg(target_os = "linux")]
    const O_NONBLOCK: i32 = 0o4000;
    #[cfg(not(target_os = "linux"))]
    const O_NONBLOCK: i32 = 0x0004;

    /// `struct pollfd` — layout fixed by POSIX.
    #[repr(C)]
    #[derive(Debug, Clone, Copy)]
    pub struct PollFd {
        pub fd: RawFd,
        pub events: i16,
        pub revents: i16,
    }

    extern "C" {
        // nfds_t is `unsigned long` on linux; `usize` matches it on
        // every LP64 target this repo builds for.
        fn poll(fds: *mut PollFd, nfds: usize, timeout_ms: i32) -> i32;
        fn pipe(fds: *mut RawFd) -> i32;
        fn fcntl(fd: RawFd, cmd: i32, arg: i32) -> i32;
        fn read(fd: RawFd, buf: *mut u8, count: usize) -> isize;
        fn write(fd: RawFd, buf: *const u8, count: usize) -> isize;
        fn close(fd: RawFd) -> i32;
    }

    /// `poll(2)` over a pollfd set, retrying on EINTR. `timeout_ms < 0`
    /// blocks until an event; `0` polls without blocking.
    pub fn poll_fds(
        fds: &mut [PollFd],
        timeout_ms: i32,
    ) -> std::io::Result<usize> {
        loop {
            let rc =
                unsafe { poll(fds.as_mut_ptr(), fds.len(), timeout_ms) };
            if rc >= 0 {
                return Ok(rc as usize);
            }
            let err = std::io::Error::last_os_error();
            if err.kind() != std::io::ErrorKind::Interrupted {
                return Err(err);
            }
        }
    }

    fn set_nonblocking(fd: RawFd) -> std::io::Result<()> {
        let flags = unsafe { fcntl(fd, F_GETFL, 0) };
        if flags < 0 {
            return Err(std::io::Error::last_os_error());
        }
        if unsafe { fcntl(fd, F_SETFL, flags | O_NONBLOCK) } < 0 {
            return Err(std::io::Error::last_os_error());
        }
        Ok(())
    }

    /// Self-pipe wakeup: the read end sits in every poll set, so any
    /// thread can interrupt a poller mid-wait by writing a byte —
    /// that's how completions, requeues, and `abort` reach a reactor
    /// blocked with an infinite timeout. Both ends are nonblocking:
    /// a full pipe on `wake` means a wakeup is already pending, which
    /// is exactly the semantics we want (no lost-wakeup race — the
    /// byte persists until drained).
    pub struct WakePipe {
        read_fd: RawFd,
        write_fd: RawFd,
    }

    impl WakePipe {
        pub fn new() -> std::io::Result<WakePipe> {
            let mut fds: [RawFd; 2] = [0; 2];
            if unsafe { pipe(fds.as_mut_ptr()) } != 0 {
                return Err(std::io::Error::last_os_error());
            }
            for fd in fds {
                if let Err(e) = set_nonblocking(fd) {
                    unsafe {
                        close(fds[0]);
                        close(fds[1]);
                    }
                    return Err(e);
                }
            }
            Ok(WakePipe { read_fd: fds[0], write_fd: fds[1] })
        }

        pub fn read_fd(&self) -> RawFd {
            self.read_fd
        }

        pub fn wake(&self) {
            let byte = [1u8];
            // EAGAIN ⇒ the pipe already holds an undrained wakeup.
            unsafe { write(self.write_fd, byte.as_ptr(), 1) };
        }

        /// Drain pending wakeup bytes (called when poll reports the
        /// read end readable).
        pub fn drain(&self) {
            let mut buf = [0u8; 256];
            loop {
                let n = unsafe {
                    read(self.read_fd, buf.as_mut_ptr(), buf.len())
                };
                if n < buf.len() as isize {
                    break;
                }
            }
        }
    }

    impl Drop for WakePipe {
        fn drop(&mut self) {
            unsafe {
                close(self.read_fd);
                close(self.write_fd);
            }
        }
    }
}

/// Per-connection receive buffer feeding the [`FrameReader`] grammar
/// incrementally: bytes accumulate across readable events, and a frame
/// pops only once it is complete. Truncation mid-frame is "need more
/// bytes" while the connection is open and a structured
/// [`FrameError`] once it hit EOF — exactly the split the blocking
/// reader gets for free from `read_exact`.
pub struct RecvBuf {
    bytes: Vec<u8>,
    max_frame_bytes: usize,
}

impl RecvBuf {
    pub fn new(max_frame_bytes: usize) -> RecvBuf {
        RecvBuf { bytes: Vec::new(), max_frame_bytes }
    }

    pub fn extend_from_slice(&mut self, chunk: &[u8]) {
        self.bytes.extend_from_slice(chunk);
    }

    pub fn is_empty(&self) -> bool {
        self.bytes.is_empty()
    }

    /// Pop the next complete frame into `out` (reused across calls),
    /// returning its payload length; `Ok(None)` when the buffered
    /// bytes do not yet hold a full frame. With `eof` set, a partial
    /// frame is a protocol violation (`TruncatedPrefix` /
    /// `TruncatedPayload`) instead of "wait for more".
    pub fn pop_frame_into(
        &mut self,
        out: &mut Vec<u8>,
        eof: bool,
    ) -> Result<Option<usize>> {
        if self.bytes.is_empty() {
            return Ok(None);
        }
        let mut fr =
            FrameReader::with_max_frame(&self.bytes[..], self.max_frame_bytes);
        match fr.read_frame_into(out) {
            Ok(Some(len)) => {
                let rest = fr.into_inner().len();
                let consumed = self.bytes.len() - rest;
                self.bytes.drain(..consumed);
                Ok(Some(len))
            }
            Ok(None) => Ok(None),
            Err(Error::Frame(
                FrameError::TruncatedPrefix
                | FrameError::TruncatedPayload { .. },
            )) if !eof => Ok(None),
            Err(e) => Err(e),
        }
    }
}

/// Nonblocking send queue with partial-write resume: frames are
/// appended whole and pumped out whenever the socket reports writable,
/// picking up exactly where the last `EWOULDBLOCK` stopped.
pub struct SendBuf {
    buf: Vec<u8>,
    pos: usize,
}

impl SendBuf {
    pub fn new() -> SendBuf {
        SendBuf { buf: Vec::new(), pos: 0 }
    }

    pub fn enqueue_frame(&mut self, payload: &[u8]) {
        write_frame_bytes(&mut self.buf, payload)
            .expect("Vec<u8> writes are infallible");
    }

    pub fn is_empty(&self) -> bool {
        self.pos >= self.buf.len()
    }

    /// Write as much queued data as the sink accepts. `Ok(true)` when
    /// fully drained, `Ok(false)` on `EWOULDBLOCK` (re-arm `POLLOUT`
    /// and resume later).
    pub fn pump<W: Write>(&mut self, w: &mut W) -> std::io::Result<bool> {
        while self.pos < self.buf.len() {
            match w.write(&self.buf[self.pos..]) {
                Ok(0) => {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::WriteZero,
                        "peer accepted zero bytes",
                    ));
                }
                Ok(n) => self.pos += n,
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock =>
                {
                    return Ok(false);
                }
                Err(e)
                    if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e),
            }
        }
        self.buf.clear();
        self.pos = 0;
        Ok(true)
    }
}

impl Default for SendBuf {
    fn default() -> Self {
        SendBuf::new()
    }
}

/// Per-connection accumulation of one machine's stream — the reactor's
/// counterpart of the threads driver's `run_assignment` body, with the
/// same validation and the same error strings (they land in attempt
/// logs and CI greps).
struct Collector {
    machine: usize,
    dim: usize,
    samples: SampleMatrix,
    draw_times: Vec<f64>,
    summary: Option<WorkerSummary>,
}

impl Collector {
    fn new(machine: usize, dim: usize) -> Collector {
        Collector {
            machine,
            dim,
            samples: SampleMatrix::new(dim),
            draw_times: Vec::new(),
            summary: None,
        }
    }

    fn on_msg(
        &mut self,
        msg: WireMsg,
        tx: &Sender<LeaderMsg>,
    ) -> Result<()> {
        let machine = self.machine;
        let dim = self.dim;
        match msg {
            WireMsg::Draw(d) => {
                if d.machine != machine || d.theta.len() != dim {
                    return Err(Error::Runtime(format!(
                        "worker {machine}: draw for machine {} with dim {}",
                        d.machine,
                        d.theta.len()
                    )));
                }
                self.samples.push(&d.theta);
                self.draw_times.push(d.elapsed);
                // Leader hung up → keep draining (mirrors thread mode).
                let _ = tx.send(LeaderMsg::Draw(d));
            }
            WireMsg::Chunk(chunk) => {
                if chunk.machine != machine
                    || chunk.dim != dim
                    || chunk.thetas.len() != chunk.elapsed.len() * dim
                {
                    return Err(Error::Runtime(format!(
                        "worker {machine}: chunk for machine {} with dim {} \
                         ({} scalars, {} rows)",
                        chunk.machine,
                        chunk.dim,
                        chunk.thetas.len(),
                        chunk.elapsed.len()
                    )));
                }
                self.samples.push_rows(&chunk.thetas);
                self.draw_times.extend_from_slice(&chunk.elapsed);
                let _ = tx.send(LeaderMsg::Chunk(chunk));
            }
            WireMsg::Summary(s) => {
                if s.machine != machine {
                    return Err(Error::Runtime(format!(
                        "worker {machine}: summary for machine {}",
                        s.machine
                    )));
                }
                self.summary = Some(s);
            }
            WireMsg::Error { machine: from, message } => {
                return Err(Error::Runtime(format!(
                    "worker {from}: remote failure: {message}"
                )));
            }
            WireMsg::Heartbeat { machine: from } => {
                if from != machine {
                    return Err(Error::Runtime(format!(
                        "worker {machine}: heartbeat for machine {from}"
                    )));
                }
                // Liveness beacon only: its arrival already re-armed
                // the connection deadline; nothing lands.
            }
        }
        Ok(())
    }

    fn finish(self) -> Result<SubposteriorSamples> {
        let machine = self.machine;
        let summary = self.summary.ok_or_else(|| {
            Error::Runtime(format!(
                "worker {machine}: stream ended without a summary frame"
            ))
        })?;
        Ok(SubposteriorSamples {
            machine,
            samples: self.samples,
            accept_rate: summary.accept_rate,
            wall_secs: summary.wall_secs,
            draw_times: self.draw_times,
        })
    }
}

/// One in-flight worker connection: a nonblocking socket plus the
/// state machine that feeds it (send queue) and drains it (receive
/// buffer → frame decoder → collector).
struct Conn {
    stream: TcpStream,
    addr: String,
    machine: usize,
    attempt: usize,
    send: SendBuf,
    recv: RecvBuf,
    /// Reused frame payload buffer — the reactor's half of the
    /// no-per-draw-allocation contract.
    frame: Vec<u8>,
    collector: Collector,
    eof: bool,
    /// Liveness deadline: re-armed whenever *any* bytes arrive (draw
    /// or heartbeat traffic both count, matching the blocking driver's
    /// per-read `set_read_timeout` semantics).
    deadline: Option<Instant>,
    started: Instant,
}

/// Everything `run_reactor` needs, lifted off the `PipelineConfig` by
/// the pipeline so this module stays independent of config plumbing.
pub struct ReactorConfig {
    /// Worker endpoint addresses (`host:port`, one per slot).
    pub addrs: Vec<String>,
    pub connect_timeout: Duration,
    /// Per-connection liveness deadline; `None` disarms.
    pub liveness: Option<Duration>,
    pub max_frame_bytes: usize,
    pub failure_policy: FailurePolicy,
    /// Re-dispatch budget per machine under the retry policy.
    pub max_retries: usize,
    /// Reactor pool size (clamped to the endpoint count).
    pub reactor_threads: usize,
    /// Parameter dimension (validated against every frame).
    pub dim: usize,
}

/// What the reactor hands back to the pipeline: per-machine results,
/// the first root-cause error, the resilience counters the threads
/// driver also reports, and the reactor-specific telemetry.
pub struct ReactorOutcome {
    pub results: Vec<Option<SubposteriorSamples>>,
    pub root_err: Option<Error>,
    pub retries: usize,
    pub quarantines: usize,
    pub missed: usize,
    /// Total `poll(2)` returns across the pool.
    pub wakeups: usize,
    /// Milliseconds from scheduler start to the first draw/chunk frame.
    pub time_to_first_draw_ms: Option<f64>,
    /// Per-endpoint busy fraction (connection-open seconds / wall).
    pub endpoint_busy: Vec<f64>,
}

/// Scheduler state shared across the reactor pool — the same fields
/// the threads driver keeps per-scope, so the two drivers make
/// identical scheduling decisions from identical inputs.
struct Shared {
    machines: usize,
    slots_total: usize,
    max_attempts: usize,
    policy: FailurePolicy,
    start: Instant,
    pending: Mutex<VecDeque<usize>>,
    attempts: Mutex<Vec<usize>>,
    attempt_log: Mutex<Vec<String>>,
    /// Failure counts per *global* endpoint slot.
    slot_failures: Mutex<Vec<usize>>,
    completed: AtomicUsize,
    live_endpoints: AtomicUsize,
    abort: AtomicBool,
    root_err: Mutex<Option<Error>>,
    results: Mutex<Vec<Option<SubposteriorSamples>>>,
    retries: AtomicUsize,
    quarantines: AtomicUsize,
    missed: AtomicUsize,
    first_draw_ms: Mutex<Option<f64>>,
    /// One self-pipe per reactor thread.
    wakes: Vec<sys::WakePipe>,
}

impl Shared {
    fn wake_all(&self) {
        for w in &self.wakes {
            w.wake();
        }
    }

    /// Record `e` as the run's root cause (first writer wins), flag
    /// the abort, and wake every poller so in-flight connections drop
    /// promptly — the reactor's `cancel_all`.
    fn fail(&self, e: Error) {
        {
            let mut first = self.root_err.lock().unwrap();
            if first.is_none() {
                *first = Some(e);
            }
        }
        self.abort.store(true, Ordering::SeqCst);
        self.wake_all();
    }

    fn note_first_draw(&self) {
        let mut g = self.first_draw_ms.lock().unwrap();
        if g.is_none() {
            *g = Some(self.start.elapsed().as_secs_f64() * 1e3);
        }
    }
}

/// Wrap a stream-level error exactly as the threads driver's
/// `run_assignment` does, so attempt logs and root causes read the
/// same under either `--io-driver`.
fn bad_frame(machine: usize, e: &Error) -> Error {
    Error::Runtime(format!(
        "worker {machine} (socket transport): bad frame: {e}"
    ))
}

/// One reactor thread: owns a strided subset of the global endpoint
/// slots and multiplexes all of their connections on a single
/// `poll(2)` loop.
struct ReactorThread<'a> {
    idx: usize,
    cfg: &'a ReactorConfig,
    shared: &'a Shared,
    manifests: &'a [WorkerManifest],
    tx: Sender<LeaderMsg>,
    /// Global slot index per local endpoint.
    slots: Vec<usize>,
    conns: Vec<Option<Conn>>,
    quarantined: Vec<bool>,
    /// Machines in capped-exponential backoff after a failure on one
    /// of this reactor's endpoints: `(release_at, machine)` — the
    /// poll-timeout analogue of the threads driver's backoff sleep.
    parked: Vec<(Instant, usize)>,
    wakeups: usize,
    busy_secs: Vec<f64>,
}

impl<'a> ReactorThread<'a> {
    fn new(
        idx: usize,
        cfg: &'a ReactorConfig,
        shared: &'a Shared,
        manifests: &'a [WorkerManifest],
        tx: Sender<LeaderMsg>,
        slots: Vec<usize>,
    ) -> ReactorThread<'a> {
        let n = slots.len();
        ReactorThread {
            idx,
            cfg,
            shared,
            manifests,
            tx,
            slots,
            conns: (0..n).map(|_| None).collect(),
            quarantined: vec![false; n],
            parked: Vec::new(),
            wakeups: 0,
            busy_secs: vec![0.0; n],
        }
    }

    fn run(mut self) -> (usize, Vec<(usize, f64)>) {
        loop {
            if self.shared.abort.load(Ordering::SeqCst) {
                self.teardown();
                break;
            }
            let now = Instant::now();
            self.release_parked(now);
            self.dispatch();
            if self.done() {
                break;
            }

            // Poll set: this reactor's wake pipe first, then every
            // live connection (write interest only while the send
            // queue holds undelivered bytes).
            let mut fds = Vec::with_capacity(1 + self.conns.len());
            fds.push(sys::PollFd {
                fd: self.shared.wakes[self.idx].read_fd(),
                events: sys::POLLIN,
                revents: 0,
            });
            let mut fd_conn = Vec::with_capacity(self.conns.len());
            for (ci, conn) in self.conns.iter().enumerate() {
                if let Some(c) = conn {
                    let mut events = sys::POLLIN;
                    if !c.send.is_empty() {
                        events |= sys::POLLOUT;
                    }
                    fds.push(sys::PollFd {
                        fd: c.stream.as_raw_fd(),
                        events,
                        revents: 0,
                    });
                    fd_conn.push(ci);
                }
            }
            let timeout = self.next_timeout_ms(Instant::now());
            if let Err(e) = sys::poll_fds(&mut fds, timeout) {
                self.shared
                    .fail(Error::Runtime(format!("reactor poll(2): {e}")));
                continue;
            }
            self.wakeups += 1;
            if fds[0].revents != 0 {
                self.shared.wakes[self.idx].drain();
            }
            for (k, &ci) in fd_conn.iter().enumerate() {
                let revents = fds[k + 1].revents;
                if revents != 0 {
                    self.service_conn(ci, revents);
                }
            }
            self.expire_deadlines(Instant::now());
        }
        let per_slot = self
            .slots
            .iter()
            .copied()
            .zip(self.busy_secs.iter().copied())
            .collect();
        (self.wakeups, per_slot)
    }

    /// All work globally done and nothing local still in flight?
    fn done(&self) -> bool {
        self.shared.completed.load(Ordering::SeqCst)
            >= self.shared.machines
            && self.conns.iter().all(Option::is_none)
            && self.parked.is_empty()
    }

    /// Move machines whose backoff elapsed back onto the shared queue
    /// (and wake the pool — an idle sibling may own the free slot).
    fn release_parked(&mut self, now: Instant) {
        let mut due = Vec::new();
        self.parked.retain(|&(release_at, m)| {
            if release_at <= now {
                due.push(m);
                false
            } else {
                true
            }
        });
        if !due.is_empty() {
            let mut q = self.shared.pending.lock().unwrap();
            for m in due {
                q.push_back(m);
            }
            drop(q);
            self.shared.wake_all();
        }
    }

    /// Assign queued machines to this reactor's free endpoints.
    fn dispatch(&mut self) {
        for ci in 0..self.conns.len() {
            if self.shared.abort.load(Ordering::SeqCst) {
                return;
            }
            if self.conns[ci].is_some() || self.quarantined[ci] {
                continue;
            }
            let m = self.shared.pending.lock().unwrap().pop_front();
            let Some(m) = m else {
                return;
            };
            let attempt = {
                let mut a = self.shared.attempts.lock().unwrap();
                a[m] += 1;
                a[m]
            };
            match self.start_conn(ci, m, attempt) {
                Ok(conn) => self.conns[ci] = Some(conn),
                Err(e) => self.on_failure(ci, m, attempt, e),
            }
        }
    }

    /// Dial one endpoint and queue the manifest (plus the inline shard
    /// when the manifest promises one). The dial itself is the
    /// bounded blocking `connect_timeout` — identical to the threads
    /// driver — and the socket goes nonblocking before any I/O.
    fn start_conn(
        &mut self,
        ci: usize,
        machine: usize,
        attempt: usize,
    ) -> Result<Conn> {
        let addr = &self.cfg.addrs[self.slots[ci]];
        let manifest = &self.manifests[machine];
        let mut resolved = addr.to_socket_addrs().map_err(|e| {
            Error::Runtime(format!("resolving worker address {addr}: {e}"))
        })?;
        let sock_addr = resolved.next().ok_or_else(|| {
            Error::Runtime(format!(
                "worker address {addr} resolved to nothing"
            ))
        })?;
        let stream =
            TcpStream::connect_timeout(&sock_addr, self.cfg.connect_timeout)
                .map_err(|e| {
                    Error::Runtime(format!(
                        "connecting to worker {addr} for machine \
                         {machine}: {e}"
                    ))
                })?;
        stream.set_nodelay(true).ok();
        stream.set_nonblocking(true).map_err(|e| {
            Error::Runtime(format!(
                "setting O_NONBLOCK on worker {addr}: {e}"
            ))
        })?;
        let mut send = SendBuf::new();
        send.enqueue_frame(manifest.to_json().render().as_bytes());
        if manifest.shard_inline {
            let bytes =
                std::fs::read(&manifest.shard_path).map_err(|e| {
                    Error::Runtime(format!(
                        "reading spilled shard {} for inline delivery: {e}",
                        manifest.shard_path
                    ))
                })?;
            if bytes.len() > self.cfg.max_frame_bytes {
                return Err(Error::Runtime(format!(
                    "machine {machine}'s shard is {} bytes, over the \
                     {}-byte inline-frame cap — raise it on both ends \
                     (`pipeline --max-frame-bytes` / the `max_frame_bytes` \
                     config key on the leader, `repro serve \
                     --max-frame-bytes` on the daemons) or use path mode \
                     (drop --shard-inline) over a shared filesystem",
                    bytes.len(),
                    self.cfg.max_frame_bytes
                )));
            }
            send.enqueue_frame(&bytes);
        }
        let now = Instant::now();
        let mut conn = Conn {
            stream,
            addr: addr.clone(),
            machine,
            attempt,
            send,
            recv: RecvBuf::new(self.cfg.max_frame_bytes),
            frame: Vec::new(),
            collector: Collector::new(machine, self.cfg.dim),
            eof: false,
            deadline: self.cfg.liveness.map(|d| now + d),
            started: now,
        };
        // Optimistic first pump: manifest (and usually the whole
        // inline shard) fits the kernel send buffer; leftovers resume
        // on POLLOUT.
        self.pump_send(&mut conn)?;
        Ok(conn)
    }

    fn pump_send(&self, c: &mut Conn) -> Result<()> {
        c.send.pump(&mut &c.stream).map(|_| ()).map_err(|e| {
            Error::Runtime(format!(
                "sending manifest for machine {} to {}: {e}",
                c.machine, c.addr
            ))
        })
    }

    /// Drain the socket and every complete frame behind it. Stream- or
    /// grammar-level trouble returns the same wrapped "bad frame"
    /// error the blocking driver produces; collector-level validation
    /// errors pass through unwrapped.
    fn drive_read(&self, c: &mut Conn) -> Result<()> {
        let mut chunk = [0u8; 65536];
        loop {
            match (&c.stream).read(&mut chunk) {
                Ok(0) => {
                    c.eof = true;
                    break;
                }
                Ok(n) => {
                    c.recv.extend_from_slice(&chunk[..n]);
                    if let Some(d) = self.cfg.liveness {
                        c.deadline = Some(Instant::now() + d);
                    }
                }
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock =>
                {
                    break;
                }
                Err(e)
                    if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => {
                    return Err(bad_frame(c.machine, &Error::Io(e)));
                }
            }
        }
        loop {
            match c.recv.pop_frame_into(&mut c.frame, c.eof) {
                Ok(Some(len)) => {
                    let msg = WireMsg::decode_frame(&c.frame[..len])
                        .map_err(|e| bad_frame(c.machine, &e))?;
                    if matches!(
                        msg,
                        WireMsg::Draw(_) | WireMsg::Chunk(_)
                    ) {
                        self.shared.note_first_draw();
                    }
                    c.collector.on_msg(msg, &self.tx)?;
                }
                Ok(None) => break,
                Err(e) => return Err(bad_frame(c.machine, &e)),
            }
        }
        Ok(())
    }

    fn service_conn(&mut self, ci: usize, revents: i16) {
        let Some(mut c) = self.conns[ci].take() else {
            return;
        };
        if revents & sys::POLLOUT != 0 {
            if let Err(e) = self.pump_send(&mut c) {
                self.conn_failed(ci, c, e);
                return;
            }
        }
        if revents
            & (sys::POLLIN | sys::POLLHUP | sys::POLLERR | sys::POLLNVAL)
            != 0
        {
            if let Err(e) = self.drive_read(&mut c) {
                self.conn_failed(ci, c, e);
                return;
            }
            if c.eof {
                self.finalize(ci, c);
                return;
            }
        }
        self.conns[ci] = Some(c);
    }

    /// Clean end-of-stream: account the slot busy time and complete or
    /// fail the machine on the summary check.
    fn finalize(&mut self, ci: usize, c: Conn) {
        self.busy_secs[ci] += c.started.elapsed().as_secs_f64();
        let (machine, attempt) = (c.machine, c.attempt);
        match c.collector.finish() {
            Ok(sub) => {
                self.shared.results.lock().unwrap()[machine] = Some(sub);
                self.shared.completed.fetch_add(1, Ordering::SeqCst);
                // Siblings idling on an empty queue exit through
                // `done()` — and the drain loop's last sender drops
                // when the pool does.
                self.shared.wake_all();
            }
            Err(e) => self.on_failure(ci, machine, attempt, e),
        }
    }

    /// Connection-level failure: drop the socket (the daemon aborts
    /// its chain at the next failed write — the reactor's
    /// `cancel_all` analogue) and route through the scheduler.
    fn conn_failed(&mut self, ci: usize, c: Conn, e: Error) {
        self.busy_secs[ci] += c.started.elapsed().as_secs_f64();
        let (machine, attempt) = (c.machine, c.attempt);
        drop(c);
        self.on_failure(ci, machine, attempt, e);
    }

    /// The scheduler's failure path — byte-for-byte the threads
    /// driver's semantics: fail-fast kills the run on the first error;
    /// retry logs the attempt, Resets the leader rows *before* any
    /// requeue, parks the machine for the capped exponential backoff,
    /// and quarantines the endpoint after `QUARANTINE_AFTER` failures.
    fn on_failure(
        &mut self,
        ci: usize,
        machine: usize,
        attempt: usize,
        e: Error,
    ) {
        let sh = self.shared;
        if sh.policy == FailurePolicy::Failfast {
            sh.fail(e);
            return;
        }
        let slot = self.slots[ci];
        let max_attempts = sh.max_attempts;
        if e.to_string().contains(LIVENESS_EXPIRED_MARKER) {
            sh.missed.fetch_add(1, Ordering::SeqCst);
        }
        sh.attempt_log.lock().unwrap().push(format!(
            "machine {machine} attempt {attempt}/{max_attempts} on \
             endpoint {slot}: {e}"
        ));
        // Discard the failed attempt's partial rows before any retry
        // traffic can land behind them; this machine has exactly one
        // live connection, so the leader's FIFO channel orders the
        // Reset after the partial stream and before the retry's.
        let _ = self.tx.send(LeaderMsg::Reset { machine });
        if attempt >= max_attempts {
            sh.fail(Error::Runtime(format!(
                "machine {machine}: retries exhausted after \
                 {max_attempts} attempts:\n  {}",
                sh.attempt_log.lock().unwrap().join("\n  ")
            )));
            return;
        }
        sh.retries.fetch_add(1, Ordering::SeqCst);
        let quarantine_now = {
            let mut sf = sh.slot_failures.lock().unwrap();
            sf[slot] += 1;
            sf[slot] >= QUARANTINE_AFTER
        };
        // Capped exponential backoff, served from the poll timeout
        // instead of a thread sleep: the machine requeues when the
        // deadline passes, and this reactor's other connections keep
        // streaming meanwhile.
        let backoff_ms = (RETRY_BACKOFF_BASE_MS << (attempt - 1).min(4))
            .min(RETRY_BACKOFF_CAP_MS);
        self.parked.push((
            Instant::now() + Duration::from_millis(backoff_ms),
            machine,
        ));
        if quarantine_now {
            sh.quarantines.fetch_add(1, Ordering::SeqCst);
            self.quarantined[ci] = true;
            if sh.live_endpoints.fetch_sub(1, Ordering::SeqCst) == 1 {
                // Last live endpoint just failed a machine: work is
                // outstanding with nowhere to run it.
                sh.fail(Error::Runtime(format!(
                    "all {} worker endpoints quarantined after repeated \
                     failures:\n  {}",
                    sh.slots_total,
                    sh.attempt_log.lock().unwrap().join("\n  ")
                )));
            }
        }
    }

    /// Liveness deadlines that passed while the poller slept — the
    /// timeout-wheel replacement for per-read `set_read_timeout`.
    fn expire_deadlines(&mut self, now: Instant) {
        for ci in 0..self.conns.len() {
            let expired = self.conns[ci]
                .as_ref()
                .and_then(|c| c.deadline)
                .is_some_and(|d| d <= now);
            if expired {
                let c = self.conns[ci].take().unwrap();
                let machine = c.machine;
                let inner = Error::Runtime(format!(
                    "{LIVENESS_EXPIRED_MARKER}: no frame (draw or \
                     heartbeat) within {:?} — peer wedged or partitioned",
                    self.cfg.liveness.unwrap_or_default()
                ));
                self.conn_failed(ci, c, bad_frame(machine, &inner));
            }
        }
    }

    /// Next poll timeout in ms: the soonest liveness deadline or
    /// backoff release, `-1` (block until an event) when neither is
    /// armed. Rounded up so a deadline never wakes the poller early
    /// into a spin.
    fn next_timeout_ms(&self, now: Instant) -> i32 {
        let mut next: Option<Instant> = None;
        for c in self.conns.iter().flatten() {
            if let Some(d) = c.deadline {
                next = Some(next.map_or(d, |n| n.min(d)));
            }
        }
        for &(release_at, _) in &self.parked {
            next = Some(next.map_or(release_at, |n| n.min(release_at)));
        }
        match next {
            None => -1,
            Some(t) => {
                let ms =
                    t.saturating_duration_since(now).as_millis() as u64;
                (ms + 1).min(i32::MAX as u64) as i32
            }
        }
    }

    /// Abort path: drop every connection (daemons abort at their next
    /// failed write) and account the busy time.
    fn teardown(&mut self) {
        for ci in 0..self.conns.len() {
            if let Some(c) = self.conns[ci].take() {
                self.busy_secs[ci] += c.started.elapsed().as_secs_f64();
            }
        }
    }
}

/// Drive every manifest to completion over the endpoint pool with a
/// `poll(2)` reactor per `reactor_threads` slice (endpoint slots are
/// strided across the pool). Blocks until all machines complete or the
/// run fails; the caller drains the leader channel concurrently and
/// reads the outcome after joining.
pub fn run_reactor(
    cfg: &ReactorConfig,
    manifests: &[WorkerManifest],
    tx: Sender<LeaderMsg>,
) -> ReactorOutcome {
    let machines = manifests.len();
    let slots_total = cfg.addrs.len().clamp(1, machines.max(1));
    let pool = cfg.reactor_threads.clamp(1, slots_total);
    let mut wakes = Vec::with_capacity(pool);
    for _ in 0..pool {
        match sys::WakePipe::new() {
            Ok(w) => wakes.push(w),
            Err(e) => {
                return ReactorOutcome {
                    results: (0..machines).map(|_| None).collect(),
                    root_err: Some(Error::Runtime(format!(
                        "creating reactor wake pipe: {e}"
                    ))),
                    retries: 0,
                    quarantines: 0,
                    missed: 0,
                    wakeups: 0,
                    time_to_first_draw_ms: None,
                    endpoint_busy: vec![0.0; slots_total],
                };
            }
        }
    }
    let shared = Shared {
        machines,
        slots_total,
        max_attempts: cfg.max_retries.saturating_add(1),
        policy: cfg.failure_policy,
        start: Instant::now(),
        pending: Mutex::new((0..machines).collect()),
        attempts: Mutex::new(vec![0; machines]),
        attempt_log: Mutex::new(Vec::new()),
        slot_failures: Mutex::new(vec![0; slots_total]),
        completed: AtomicUsize::new(0),
        live_endpoints: AtomicUsize::new(slots_total),
        abort: AtomicBool::new(false),
        root_err: Mutex::new(None),
        results: Mutex::new((0..machines).map(|_| None).collect()),
        retries: AtomicUsize::new(0),
        quarantines: AtomicUsize::new(0),
        missed: AtomicUsize::new(0),
        first_draw_ms: Mutex::new(None),
        wakes,
    };

    let mut per_thread: Vec<(usize, Vec<(usize, f64)>)> = Vec::new();
    let mut panicked = false;
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..pool)
            .map(|r| {
                let tx = tx.clone();
                let shared = &shared;
                scope.spawn(move || {
                    let slots: Vec<usize> =
                        (r..slots_total).step_by(pool).collect();
                    ReactorThread::new(r, cfg, shared, manifests, tx, slots)
                        .run()
                })
            })
            .collect();
        drop(tx);
        for h in handles {
            match h.join() {
                Ok(out) => per_thread.push(out),
                Err(_) => panicked = true,
            }
        }
    });
    if panicked {
        shared.fail(Error::Runtime("reactor thread panicked".into()));
    }

    let wall = shared.start.elapsed().as_secs_f64().max(f64::EPSILON);
    let mut endpoint_busy = vec![0.0; slots_total];
    let mut wakeups = 0usize;
    for (w, per_slot) in per_thread {
        wakeups += w;
        for (slot, busy) in per_slot {
            endpoint_busy[slot] = (busy / wall).min(1.0);
        }
    }

    ReactorOutcome {
        results: shared.results.into_inner().unwrap(),
        root_err: shared.root_err.into_inner().unwrap(),
        retries: shared.retries.load(Ordering::SeqCst),
        quarantines: shared.quarantines.load(Ordering::SeqCst),
        missed: shared.missed.load(Ordering::SeqCst),
        wakeups,
        time_to_first_draw_ms: shared.first_draw_ms.into_inner().unwrap(),
        endpoint_busy,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::transport::{
        encode_draw, encode_summary, WireFormat,
    };
    use crate::coordinator::worker::DrawMsg;
    use std::io::BufReader;
    use std::net::TcpListener;
    use std::sync::mpsc::channel;

    fn frame(payload: &[u8]) -> Vec<u8> {
        let mut buf = Vec::new();
        write_frame_bytes(&mut buf, payload).unwrap();
        buf
    }

    /// Satellite edge case: a frame straddling two readable events —
    /// split mid-prefix and mid-payload — assembles once the remainder
    /// lands, and back-to-back frames in one buffer pop in order.
    #[test]
    fn recv_buf_assembles_frames_split_across_events() {
        let payload = b"hello, reactor".to_vec();
        let wire = frame(&payload);
        let mut out = Vec::new();
        for split in 1..wire.len() {
            let mut rb = RecvBuf::new(1024);
            rb.extend_from_slice(&wire[..split]);
            assert!(
                rb.pop_frame_into(&mut out, false).unwrap().is_none(),
                "partial frame (split at {split}) must wait for more bytes"
            );
            rb.extend_from_slice(&wire[split..]);
            let len = rb.pop_frame_into(&mut out, false).unwrap().unwrap();
            assert_eq!(&out[..len], &payload[..]);
            assert!(rb.is_empty());
        }
        // Two frames delivered in one readable event.
        let mut rb = RecvBuf::new(1024);
        rb.extend_from_slice(&frame(b"first"));
        rb.extend_from_slice(&frame(b"second"));
        let n1 = rb.pop_frame_into(&mut out, false).unwrap().unwrap();
        assert_eq!(&out[..n1], b"first");
        let n2 = rb.pop_frame_into(&mut out, false).unwrap().unwrap();
        assert_eq!(&out[..n2], b"second");
        assert!(rb.pop_frame_into(&mut out, false).unwrap().is_none());
    }

    /// A partial frame is "need more bytes" while the stream is open
    /// and a structured truncation once it hit EOF; grammar violations
    /// surface immediately either way.
    #[test]
    fn recv_buf_truncation_surfaces_at_eof() {
        let mut out = Vec::new();
        let mut rb = RecvBuf::new(1024);
        rb.extend_from_slice(b"12"); // prefix missing its newline
        assert!(rb.pop_frame_into(&mut out, false).unwrap().is_none());
        assert!(matches!(
            rb.pop_frame_into(&mut out, true),
            Err(Error::Frame(FrameError::TruncatedPrefix))
        ));

        let mut rb = RecvBuf::new(1024);
        rb.extend_from_slice(b"5\nab"); // payload cut mid-frame
        assert!(rb.pop_frame_into(&mut out, false).unwrap().is_none());
        assert!(matches!(
            rb.pop_frame_into(&mut out, true),
            Err(Error::Frame(FrameError::TruncatedPayload { expected: 5 }))
        ));

        let mut rb = RecvBuf::new(1024);
        rb.extend_from_slice(b"xyz\n"); // corrupt prefix: instant error
        assert!(matches!(
            rb.pop_frame_into(&mut out, false),
            Err(Error::Frame(FrameError::BadPrefix(_)))
        ));
    }

    /// Accepts 3 bytes per call and returns `EWOULDBLOCK` on every
    /// other call — the worst-case trickle sink.
    struct Trickle {
        out: Vec<u8>,
        calls: usize,
    }

    impl Write for Trickle {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            self.calls += 1;
            if self.calls % 2 == 0 {
                return Err(std::io::ErrorKind::WouldBlock.into());
            }
            let n = buf.len().min(3);
            self.out.extend_from_slice(&buf[..n]);
            Ok(n)
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    /// Satellite edge case: a manifest write interrupted by
    /// `EWOULDBLOCK` resumes from the exact byte, over as many
    /// writable events as it takes.
    #[test]
    fn send_buf_resumes_partial_writes() {
        let manifest_ish = vec![7u8; 100];
        let mut sb = SendBuf::new();
        sb.enqueue_frame(&manifest_ish);
        let expected = frame(&manifest_ish);
        let mut sink = Trickle { out: Vec::new(), calls: 0 };
        let mut pumps = 0;
        while !sb.pump(&mut sink).unwrap() {
            pumps += 1;
            assert!(pumps < 10_000, "pump never drained");
        }
        assert!(pumps > 1, "trickle sink must force multiple resumes");
        assert_eq!(sink.out, expected);
        assert!(sb.is_empty());
    }

    /// Satellite edge case: a wake (the `cancel_all` path) interrupts
    /// a poller blocked on a long timeout.
    #[test]
    fn wake_pipe_interrupts_poll_mid_wait() {
        let wp = std::sync::Arc::new(sys::WakePipe::new().unwrap());
        let waker = wp.clone();
        let t0 = Instant::now();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(50));
            waker.wake();
        });
        let mut fds = [sys::PollFd {
            fd: wp.read_fd(),
            events: sys::POLLIN,
            revents: 0,
        }];
        let n = sys::poll_fds(&mut fds, 10_000).unwrap();
        assert_eq!(n, 1, "wake byte must be reported as readable");
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "poll must return on the wake, not the timeout"
        );
        wp.drain();
        // Drained: an immediate re-poll reports nothing.
        let n = sys::poll_fds(&mut fds, 0).unwrap();
        assert_eq!(n, 0);
        h.join().unwrap();
    }

    fn manifest(machine: usize, dim: usize) -> WorkerManifest {
        WorkerManifest {
            machine,
            machines: 1,
            seed: 7,
            samples: 1,
            burn_in: 0,
            thin: 1,
            prior_weight: 1.0,
            sampler: "rwm:0.5".into(),
            shard_path: "unused-by-reactor-tests".into(),
            dim,
            shard_inline: false,
            wire_format: WireFormat::Json,
            draw_batch: 1,
            heartbeat_secs: 0,
        }
    }

    fn rcfg(addrs: Vec<String>) -> ReactorConfig {
        ReactorConfig {
            addrs,
            connect_timeout: Duration::from_secs(5),
            liveness: None,
            max_frame_bytes: 1 << 20,
            failure_policy: FailurePolicy::Failfast,
            max_retries: 0,
            reactor_threads: 1,
            dim: 1,
        }
    }

    /// Full loop against a scripted in-process server: manifest out,
    /// one draw + summary back, clean close — the machine completes
    /// and the telemetry counters move.
    #[test]
    fn reactor_completes_a_scripted_stream() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut reader =
                FrameReader::new(BufReader::new(stream.try_clone().unwrap()));
            let m = reader.read_frame().unwrap().expect("manifest frame");
            assert!(m.contains("\"machine\""));
            let mut w = &stream;
            let draw = encode_draw(&DrawMsg {
                machine: 0,
                theta: vec![1.5],
                elapsed: 0.1,
                last: true,
            });
            write_frame_bytes(&mut w, draw.as_bytes()).unwrap();
            let summary = encode_summary(&WorkerSummary {
                machine: 0,
                accept_rate: 0.5,
                wall_secs: 0.1,
            });
            write_frame_bytes(&mut w, summary.as_bytes()).unwrap();
        });
        let (tx, rx) = channel();
        let cfg = rcfg(vec![addr]);
        let out = run_reactor(&cfg, &[manifest(0, 1)], tx);
        server.join().unwrap();
        assert!(out.root_err.is_none(), "{:?}", out.root_err);
        let sub = out.results[0].as_ref().expect("machine 0 completed");
        assert_eq!(sub.samples.len(), 1);
        assert_eq!(sub.draw_times, vec![0.1]);
        assert!((sub.accept_rate - 0.5).abs() < 1e-12);
        assert!(out.wakeups > 0, "poll must have woken at least once");
        assert!(out.time_to_first_draw_ms.is_some());
        assert_eq!(out.endpoint_busy.len(), 1);
        // The leader channel saw the draw before the reactor returned.
        assert!(matches!(rx.try_recv(), Ok(LeaderMsg::Draw(_))));
    }

    /// Satellite edge case: a liveness deadline expires from the poll
    /// timeout (no bytes ever arrive after the accept) and surfaces
    /// the same structured marker the blocking driver raises — and
    /// under retry with an exhausted budget it counts a missed
    /// heartbeat.
    #[test]
    fn liveness_expiry_fires_from_poll_timeout() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            // Hold the connection open, silently, past the deadline.
            std::thread::sleep(Duration::from_millis(1200));
            drop(stream);
        });
        let (tx, _rx) = channel();
        let mut cfg = rcfg(vec![addr]);
        cfg.liveness = Some(Duration::from_millis(300));
        cfg.failure_policy = FailurePolicy::Retry;
        cfg.max_retries = 0; // one attempt: first expiry is terminal
        let t0 = Instant::now();
        let out = run_reactor(&cfg, &[manifest(0, 1)], tx);
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "expiry must fire from the poll timeout, not hang"
        );
        let err = out.root_err.expect("run must fail").to_string();
        assert!(
            err.contains(LIVENESS_EXPIRED_MARKER),
            "unexpected root cause: {err}"
        );
        assert_eq!(out.missed, 1);
        assert!(out.results[0].is_none());
        server.join().unwrap();
    }

    /// Satellite edge case: a fail-fast abort on one reactor wakes a
    /// sibling blocked in an infinite poll on a silent connection.
    #[test]
    fn failfast_abort_wakes_sibling_poller() {
        let silent = TcpListener::bind("127.0.0.1:0").unwrap();
        let silent_addr = silent.local_addr().unwrap().to_string();
        let keeper = std::thread::spawn(move || {
            let (stream, _) = silent.accept().ok()?;
            std::thread::sleep(Duration::from_millis(100));
            Some(stream)
        });
        // A port with nothing listening: bind, learn the port, drop.
        let refused_addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let (tx, _rx) = channel();
        let mut cfg = rcfg(vec![silent_addr.clone(), refused_addr]);
        cfg.reactor_threads = 2; // one poller per endpoint
        let t0 = Instant::now();
        let out = run_reactor(
            &cfg,
            &[manifest(0, 1), manifest(1, 1)],
            tx,
        );
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "abort must wake the sibling poller, not wait out its poll"
        );
        let err = out.root_err.expect("refused dial must fail the run");
        assert!(
            err.to_string().contains("connecting to worker"),
            "unexpected root cause: {err}"
        );
        // If the abort won the race before the silent endpoint was
        // ever dialed, unblock its accept so the thread can exit.
        let _ = TcpStream::connect(&silent_addr);
        let _ = keeper.join();
    }
}
