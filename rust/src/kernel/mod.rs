//! Pluggable compute kernels for the combination stage.
//!
//! The combination stage's serial cost is dominated by three dense
//! operations: the O(TMd²) per-machine parametric log-density table of
//! the semiparametric combiner, the O(d³)-per-iteration factorizations
//! behind the [`AnnealCache`](crate::combine::semiparametric::AnnealCache),
//! and the O(Td) squared-norm cache every IMG chain reads. This module
//! turns those into a *backend seam*: a [`CombineKernel`] trait with
//! three implementations —
//!
//! * [`NaiveKernel`] — the scalar loops extracted verbatim from the
//!   combine layer; the bit-exact reference every other backend is
//!   pinned against.
//! * [`BlockedCpuKernel`] — cache-blocked column panels for the
//!   log-density table and batched triangular solves for the SPD
//!   inverse. Per-entry accumulation order is **identical** to the
//!   naive kernel, so retained draws stay byte-for-byte the same at any
//!   thread count (asserted by `rust/tests/kernel_parity.rs` and the
//!   `micro_hotpath` bench gate); the speedup comes purely from
//!   instruction-level parallelism — panels break the one-accumulator
//!   dependency chains of the scalar solves into many independent ones.
//! * [`DeviceKernel`] — the same table op lowered through the
//!   [`crate::runtime::xla_shim`] PJRT surface: the mount point for the
//!   future Pallas combine kernel. Offline (no vendored bindings) it
//!   fails fast with a structured [`Error::KernelUnavailable`], never a
//!   panic.
//!
//! The selected kernel is installed into
//! [`CombineContext`](crate::combine::CombineContext) and dispatched
//! from the semiparametric, nonparametric and pairwise combiners; the
//! `combine_backend` config key / `--combine-backend` CLI flag selects
//! it per run.

pub mod blocked;
pub mod device;
pub mod naive;

pub use blocked::BlockedCpuKernel;
pub use device::DeviceKernel;
pub use naive::NaiveKernel;

use std::fmt;
use std::sync::Arc;

use crate::error::{Error, Result};
use crate::math::linalg::Mat;
use crate::math::mvn::Mvn;
use crate::types::SampleMatrix;

/// Dense combine-stage operations behind a swappable backend.
///
/// Every method is a pure function of its inputs (no hidden state), so
/// the combine layer's determinism contract — byte-identical draws for
/// a fixed seed at any thread count — holds whenever two backends are
/// value-identical. The naive and blocked CPU backends are *bit*
/// identical by construction (same per-entry accumulation order);
/// device backends are explicitly allowed to differ and are therefore
/// never the default.
pub trait CombineKernel: fmt::Debug + Send + Sync {
    /// Backend name for diagnostics and bench rows.
    fn name(&self) -> &'static str;

    /// One machine's column of the O(TMd²) parametric log-density
    /// table: `log N(θ_t | μ, Σ)` for every draw `θ_t` in `set`,
    /// against a pre-factored [`Mvn`]. Entry `t` must equal
    /// `mvn.logpdf(set.row(t))` (bit-exactly for CPU backends).
    fn logpdf_table(&self, mvn: &Mvn, set: &SampleMatrix) -> Result<Vec<f64>>;

    /// Replace the SPD matrix `a` with its inverse, using the shared
    /// diagonal-jitter escalation policy
    /// ([`crate::math::linalg::jittered_cholesky`]). This is the
    /// annealed-factorization hot path: the `AnnealCache` build calls
    /// it once per cached iteration (in parallel), and uncached chains
    /// call it in place per iteration. CPU backends must match
    /// [`crate::math::linalg::spd_inverse_jittered_in_place`]
    /// bit-for-bit.
    fn spd_inverse_in_place(&self, a: &mut Mat) -> Result<()>;

    /// Per-draw squared norms `|θ_t|²` of one sample set — the O(1)
    /// `Q_t` update cache every IMG chain (nonparametric,
    /// semiparametric, pairwise merges) reads. Entry `t` must equal
    /// `set.row(t).iter().map(|v| v * v).sum()` accumulated in index
    /// order.
    fn row_norms(&self, set: &SampleMatrix) -> Result<Vec<f64>>;

    /// Chunk-streaming counterpart of [`CombineKernel::logpdf_table`]:
    /// append the log-densities of one flat row-major `block` of draws
    /// (dim `mvn.dim()`, whole rows) onto `out`. Per-entry values must
    /// be *block-boundary independent* — streaming a set through any
    /// chunking of this method reproduces `logpdf_table` bit-for-bit —
    /// which is what lets the chunked [`crate::types::DrawStore`] feed
    /// the combine stage without densifying. The default materializes
    /// the block as a temporary [`SampleMatrix`] and defers to
    /// `logpdf_table`, so backends that only implement the dense op
    /// (e.g. the device backend) stay correct; CPU backends override it
    /// to skip the copy.
    fn logpdf_table_block(
        &self,
        mvn: &Mvn,
        block: &[f64],
        out: &mut Vec<f64>,
    ) -> Result<()> {
        let set = SampleMatrix::from_rows(block.to_vec(), mvn.dim())?;
        out.extend(self.logpdf_table(mvn, &set)?);
        Ok(())
    }

    /// Chunk-streaming counterpart of [`CombineKernel::row_norms`]:
    /// append per-row squared norms of one flat row-major `block` (dim
    /// `dim`, whole rows) onto `out`. Same block-boundary-independence
    /// contract as [`CombineKernel::logpdf_table_block`]; same
    /// densifying default.
    fn row_norms_block(
        &self,
        block: &[f64],
        dim: usize,
        out: &mut Vec<f64>,
    ) -> Result<()> {
        let set = SampleMatrix::from_rows(block.to_vec(), dim)?;
        out.extend(self.row_norms(&set)?);
        Ok(())
    }
}

/// Which combine-kernel backend to run — the `combine_backend` config
/// key / `--combine-backend` CLI flag.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CombineKernelKind {
    /// Scalar reference loops (the default: bit-exact, zero risk).
    #[default]
    Naive,
    /// Cache-blocked CPU panels, bit-identical to `Naive`.
    Blocked,
    /// PJRT-lowered device kernel (requires vendored bindings; fails
    /// with a structured error offline).
    Device,
}

impl CombineKernelKind {
    pub fn name(&self) -> &'static str {
        match self {
            CombineKernelKind::Naive => "naive",
            CombineKernelKind::Blocked => "blocked",
            CombineKernelKind::Device => "device",
        }
    }

    /// All backends, for sweeps and `--help` text.
    pub fn all() -> &'static [CombineKernelKind] {
        &[
            CombineKernelKind::Naive,
            CombineKernelKind::Blocked,
            CombineKernelKind::Device,
        ]
    }

    pub fn parse(s: &str) -> Result<CombineKernelKind> {
        CombineKernelKind::all()
            .iter()
            .copied()
            .find(|k| k.name().eq_ignore_ascii_case(s.trim()))
            .ok_or_else(|| {
                Error::Config(format!(
                    "unknown combine backend '{s}' (expected naive | \
                     blocked | device)"
                ))
            })
    }

    /// Instantiate the backend. `Device` fails here — not at first use
    /// deep inside a combine call — when no PJRT runtime is available,
    /// so a misconfigured run dies with a clear
    /// [`Error::KernelUnavailable`] before any sampling work is spent.
    pub fn build(&self) -> Result<Arc<dyn CombineKernel>> {
        Ok(match self {
            CombineKernelKind::Naive => Arc::new(NaiveKernel),
            CombineKernelKind::Blocked => {
                Arc::new(BlockedCpuKernel::default())
            }
            CombineKernelKind::Device => Arc::new(DeviceKernel::new()?),
        })
    }
}

/// The reference backend as a shared handle — what every legacy entry
/// point (no explicit backend) runs on.
pub fn default_kernel() -> Arc<dyn CombineKernel> {
    Arc::new(NaiveKernel)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parse_roundtrip() {
        for &k in CombineKernelKind::all() {
            assert_eq!(CombineKernelKind::parse(k.name()).unwrap(), k);
        }
        assert_eq!(
            CombineKernelKind::parse(" BLOCKED ").unwrap(),
            CombineKernelKind::Blocked
        );
        assert!(CombineKernelKind::parse("cuda").is_err());
        assert_eq!(CombineKernelKind::default(), CombineKernelKind::Naive);
    }

    #[test]
    fn cpu_backends_build() {
        for kind in [CombineKernelKind::Naive, CombineKernelKind::Blocked] {
            let k = kind.build().unwrap();
            assert_eq!(k.name(), kind.name());
        }
    }

    /// A backend that only implements the dense ops (as the device
    /// backend does) still serves the chunk-streaming calls correctly
    /// through the trait's densifying defaults.
    #[derive(Debug)]
    struct DenseOnly;

    impl CombineKernel for DenseOnly {
        fn name(&self) -> &'static str {
            "dense-only"
        }
        fn logpdf_table(
            &self,
            mvn: &Mvn,
            set: &SampleMatrix,
        ) -> Result<Vec<f64>> {
            NaiveKernel.logpdf_table(mvn, set)
        }
        fn spd_inverse_in_place(&self, a: &mut Mat) -> Result<()> {
            NaiveKernel.spd_inverse_in_place(a)
        }
        fn row_norms(&self, set: &SampleMatrix) -> Result<Vec<f64>> {
            NaiveKernel.row_norms(set)
        }
    }

    #[test]
    fn default_block_impls_match_dense_ops() {
        let cov = Mat::from_vec(vec![2.0, 0.3, 0.3, 1.0], 2, 2).unwrap();
        let mvn = Mvn::new(vec![0.1, -0.4], cov).unwrap();
        let mut rng = crate::rng::Pcg64::seed_from(31);
        let set = mvn.sample_n(11, &mut rng);
        let want = DenseOnly.logpdf_table(&mvn, &set).unwrap();
        let mut got = Vec::new();
        for block in set.rows_chunked(4) {
            DenseOnly.logpdf_table_block(&mvn, block, &mut got).unwrap();
        }
        assert_eq!(want.len(), got.len());
        for (w, g) in want.iter().zip(&got) {
            assert_eq!(w.to_bits(), g.to_bits());
        }
        let want = DenseOnly.row_norms(&set).unwrap();
        let mut got = Vec::new();
        for block in set.rows_chunked(3) {
            DenseOnly.row_norms_block(block, set.dim(), &mut got).unwrap();
        }
        assert_eq!(want, got);
    }

    /// Offline, the device backend is a structured error at build time
    /// — never a panic, never a silent fallback.
    #[test]
    fn device_backend_unavailable_offline_is_structured() {
        let err = CombineKernelKind::Device.build().unwrap_err();
        match &err {
            Error::KernelUnavailable { backend, reason } => {
                assert_eq!(*backend, "device");
                assert!(
                    reason.contains("not available"),
                    "reason should carry the PJRT stub's message: {reason}"
                );
            }
            other => panic!("expected KernelUnavailable, got {other:?}"),
        }
        // The rendered message names the backend for CLI users.
        assert!(err.to_string().contains("device"), "{err}");
    }
}
