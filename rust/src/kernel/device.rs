//! Device backend: the combine-table op lowered through the PJRT
//! surface — the mount point for the future Pallas combine kernel.
//!
//! The sampler side already has a python → HLO → PJRT path
//! (`python/compile/` lowers likelihood kernels, `runtime/` executes
//! them); the combine stage had none. This backend gives it the same
//! shape: [`DeviceKernel::new`] opens a PJRT client through
//! [`crate::runtime::xla_shim`], and [`CombineKernel::logpdf_table`]
//! stages the factor/mean/draws as device buffers and executes the
//! `combine_logpdf_table` HLO artifact ([`COMBINE_TABLE_ARTIFACT`])
//! once one is lowered.
//!
//! Offline — this crate vendors no PJRT bindings, `xla_shim` fails
//! every fallible call — construction returns a **structured**
//! [`Error::KernelUnavailable`] ("backend unavailable"), so
//! `--combine-backend device` is a clean, diagnosable error and never
//! a panic. The kernel parity gates apply to the CPU backends only:
//! device results are f32 and explicitly *not* bit-identical, which is
//! why this backend must always be selected explicitly.
//!
//! Note on threading: the offline stub's client is a unit struct and
//! trivially `Send + Sync`; the real `xla` bindings are `Rc`-based, so
//! vendoring them will need a per-thread client handle here (the same
//! constraint `runtime/client.rs` documents).

use std::fmt;

use super::CombineKernel;
use crate::error::{Error, Result};
use crate::math::linalg::Mat;
use crate::math::mvn::Mvn;
use crate::runtime::xla_shim as xla;
use crate::types::SampleMatrix;

/// Artifact name the device table op executes — the contract for the
/// python side's future Pallas lowering: inputs `(rows: [t, d],
/// mean: [d], chol: [d, d], log_norm: [])`, output `table: [t]`.
pub const COMBINE_TABLE_ARTIFACT: &str = "combine_logpdf_table";

/// PJRT-backed combine kernel (`--combine-backend device`).
pub struct DeviceKernel {
    client: xla::PjRtClient,
}

impl fmt::Debug for DeviceKernel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("DeviceKernel")
            .field("platform", &self.client.platform_name())
            .finish()
    }
}

impl DeviceKernel {
    /// Open a PJRT client for the combine table op. Offline this is
    /// where `--combine-backend device` fails — before any sampling or
    /// combine work is spent — with a structured
    /// [`Error::KernelUnavailable`] carrying the stub's reason.
    pub fn new() -> Result<DeviceKernel> {
        let client =
            xla::PjRtClient::cpu().map_err(|e| Error::KernelUnavailable {
                backend: "device",
                reason: e.to_string(),
            })?;
        Ok(DeviceKernel { client })
    }

    /// Structured "op not lowered yet" error: the client exists (so a
    /// runtime *is* available) but the combine-stage artifact has not
    /// been lowered — name exactly what is missing.
    fn not_lowered(op: &str) -> Error {
        Error::KernelUnavailable {
            backend: "device",
            reason: format!(
                "op '{op}' needs the {COMBINE_TABLE_ARTIFACT} HLO \
                 artifact (not lowered yet; see python/compile)"
            ),
        }
    }
}

impl CombineKernel for DeviceKernel {
    fn name(&self) -> &'static str {
        "device"
    }

    /// Stage the table inputs on the device. Execution requires the
    /// [`COMBINE_TABLE_ARTIFACT`] HLO; until the Pallas lowering lands
    /// this returns the structured not-lowered error after the buffers
    /// round-trip (which exercises the real PJRT staging path when
    /// bindings are vendored).
    fn logpdf_table(
        &self,
        mvn: &Mvn,
        set: &SampleMatrix,
    ) -> Result<Vec<f64>> {
        super::naive::check_dims(mvn, set)?;
        let d = mvn.dim();
        let rows: Vec<f32> =
            set.as_slice().iter().map(|&v| v as f32).collect();
        let mean: Vec<f32> = mvn.mean().iter().map(|&v| v as f32).collect();
        let chol: Vec<f32> =
            mvn.chol().as_slice().iter().map(|&v| v as f32).collect();
        let _rows_buf =
            self.client.buffer_from_host_buffer(&rows, &[set.len(), d], None)?;
        let _mean_buf = self.client.buffer_from_host_buffer(&mean, &[d], None)?;
        let _chol_buf = self.client.buffer_from_host_buffer(&chol, &[d, d], None)?;
        let _norm_buf = self
            .client
            .buffer_from_host_buffer(&[mvn.log_norm() as f32], &[], None)?;
        Err(Self::not_lowered("logpdf_table"))
    }

    /// Dense d×d inverses are far below the device dispatch
    /// break-even; there is no device op for them by design.
    fn spd_inverse_in_place(&self, _a: &mut Mat) -> Result<()> {
        Err(Self::not_lowered("spd_inverse_in_place"))
    }

    fn row_norms(&self, _set: &SampleMatrix) -> Result<Vec<f64>> {
        Err(Self::not_lowered("row_norms"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Offline, construction is the failure point and the error is the
    /// structured variant with the stub's reason — no panics anywhere.
    #[test]
    fn offline_construction_fails_structured() {
        let err = DeviceKernel::new().unwrap_err();
        match err {
            Error::KernelUnavailable { backend, reason } => {
                assert_eq!(backend, "device");
                assert!(reason.contains("offline stub"), "{reason}");
            }
            other => panic!("expected KernelUnavailable, got {other:?}"),
        }
    }
}
