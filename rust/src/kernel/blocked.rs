//! Cache-blocked CPU backend.
//!
//! The scalar reference solves are *latency*-bound, not flop-bound:
//! a forward substitution carries one accumulator through `d` dependent
//! fused multiply-subtracts, so the core idles for the FMA latency on
//! every step. This backend reorganizes the same arithmetic over
//! **panels** — a block of table rows (or all `d` inverse columns) is
//! solved simultaneously, with the loop over panel lanes *innermost* —
//! which gives the CPU `panel` independent dependency chains to overlap
//! and a contiguous unit-stride inner loop to vectorize.
//!
//! ## Bit-identity contract
//!
//! Reordering is only across independent table entries / inverse
//! columns, never *within* one entry's accumulation: every entry still
//! starts from the same value, subtracts the same products in the same
//! (ascending-`k`) order, and divides by the same pivot. The results
//! are therefore bit-identical to [`NaiveKernel`](super::NaiveKernel)
//! — pinned by the unit tests below, `rust/tests/kernel_parity.rs`
//! (whole-combiner byte-identity at 1/2/4 threads, including
//! non-finite table entries), and the `micro_hotpath` bench, which
//! hard-fails if this backend ever stops beating the reference.

use super::naive::check_dims;
use super::CombineKernel;
use crate::error::Result;
use crate::math::linalg::{self, Mat};
use crate::math::mvn::Mvn;
use crate::types::SampleMatrix;

/// Rows per column panel of the log-density table solve: enough
/// independent dependency chains to hide FMA latency and fill a SIMD
/// register file, small enough that a d×panel f64 panel stays in L1
/// for the d ≲ 100 regime the combiners run in.
const PANEL_ROWS: usize = 32;

/// Cache-blocked CPU kernel (`--combine-backend blocked`).
#[derive(Debug, Clone)]
pub struct BlockedCpuKernel {
    panel_rows: usize,
}

impl Default for BlockedCpuKernel {
    fn default() -> Self {
        BlockedCpuKernel { panel_rows: PANEL_ROWS }
    }
}

impl BlockedCpuKernel {
    /// Kernel with an explicit panel width (tests sweep odd widths to
    /// pin the remainder-panel path; results are identical at any
    /// width ≥ 1).
    pub fn with_panel_rows(panel_rows: usize) -> Self {
        BlockedCpuKernel { panel_rows: panel_rows.max(1) }
    }

    /// One ≤`panel_rows` panel of the table solve over a flat row-major
    /// `block` — the shared body behind the dense `logpdf_table` and
    /// the chunk-streaming `logpdf_table_block`. `panel`/`acc` are
    /// caller-owned scratch of at least `d·width` / `width` scalars.
    fn table_panel(
        &self,
        mvn: &Mvn,
        block: &[f64],
        panel: &mut [f64],
        acc: &mut [f64],
        out: &mut Vec<f64>,
    ) {
        let d = mvn.dim();
        let l = mvn.chol();
        let mean = mvn.mean();
        let log_norm = mvn.log_norm();
        let r = block.len() / d;
        // Transposed residuals: same subtraction as the scalar
        // path's `scratch[i] = x[i] - mean[i]`, laid out lane-major.
        for i in 0..d {
            let mi = mean[i];
            let yi = &mut panel[i * r..(i + 1) * r];
            for (t, y) in yi.iter_mut().enumerate() {
                *y = block[t * d + i] - mi;
            }
        }
        // Forward substitution, panel-wide. Entry (i, t) starts at
        // its residual, subtracts L[i][k]·y[k][t] for k ascending,
        // then divides by the pivot — the scalar
        // `forward_solve_in_place` op sequence per entry, with the
        // lane loop innermost for ILP/SIMD.
        for i in 0..d {
            let (solved, active) = panel.split_at_mut(i * r);
            let yi = &mut active[..r];
            for k in 0..i {
                let lik = l[(i, k)];
                let yk = &solved[k * r..(k + 1) * r];
                for (y, &v) in yi.iter_mut().zip(yk) {
                    *y -= lik * v;
                }
            }
            let lii = l[(i, i)];
            for y in yi.iter_mut() {
                *y /= lii;
            }
        }
        // |y_t|² accumulated over i ascending from 0.0 — the same
        // fold order as `linalg::dot`'s iterator sum.
        for a in acc[..r].iter_mut() {
            *a = 0.0;
        }
        for i in 0..d {
            let yi = &panel[i * r..(i + 1) * r];
            for (a, &v) in acc[..r].iter_mut().zip(yi) {
                *a += v * v;
            }
        }
        for &a in &acc[..r] {
            out.push(log_norm - 0.5 * a);
        }
    }
}

impl CombineKernel for BlockedCpuKernel {
    fn name(&self) -> &'static str {
        "blocked"
    }

    /// Whitened-quadratic-form table over column panels.
    ///
    /// Per panel of `r ≤ panel_rows` draws: load the transposed
    /// residual panel `y[i][t] = θ_t[i] − μ[i]` (coordinate-major, so
    /// lane loops are unit-stride), forward-solve `L y = resid` with
    /// the lane loop innermost, then reduce `|y_t|²` in ascending-`i`
    /// order — each per-entry operation sequence is exactly
    /// [`Mvn::logpdf_with`]'s.
    fn logpdf_table(
        &self,
        mvn: &Mvn,
        set: &SampleMatrix,
    ) -> Result<Vec<f64>> {
        check_dims(mvn, set)?;
        let d = mvn.dim();
        let width = self.panel_rows;
        let mut out = Vec::with_capacity(set.len());
        let mut panel = vec![0.0f64; d * width];
        let mut acc = vec![0.0f64; width];
        for block in set.rows_chunked(width) {
            self.table_panel(mvn, block, &mut panel, &mut acc, &mut out);
        }
        Ok(out)
    }

    /// Jittered SPD inverse with the `d` basis-column solves batched
    /// into one blocked triangular solve pair (ROADMAP rung (d)).
    ///
    /// The factor comes from the same [`linalg::jittered_cholesky`]
    /// escalation policy as the scalar path; the forward pass solves
    /// `L Y = I` with the column loop innermost, the backward pass
    /// solves `Lᵀ X = Y` in place, and the result is symmetrized with
    /// the same [`Mat::symmetrize`] — so every element matches
    /// [`linalg::spd_inverse_jittered_in_place`] bit-for-bit while the
    /// inner loops run over contiguous rows instead of one
    /// latency-chained column at a time.
    fn spd_inverse_in_place(&self, a: &mut Mat) -> Result<()> {
        let l = linalg::jittered_cholesky(a)?;
        let n = l.rows();
        let mut y = Mat::zeros(n, n);
        // Forward: row i of Y starts at row i of I, subtracts
        // L[i][k]·Y[k] for k ascending, divides by the pivot — per
        // column j this is exactly `forward_solve(l, e_j)`.
        for i in 0..n {
            y[(i, i)] = 1.0;
            for k in 0..i {
                let lik = l[(i, k)];
                for j in 0..n {
                    let v = y[(k, j)];
                    y[(i, j)] -= lik * v;
                }
            }
            let lii = l[(i, i)];
            for j in 0..n {
                y[(i, j)] /= lii;
            }
        }
        // Backward, in place: row i starts at its forward value,
        // subtracts L[k][i]·X[k] for k ascending in (i+1)..n, divides —
        // per column j exactly `backward_solve(l, y_j)`.
        for i in (0..n).rev() {
            for k in (i + 1)..n {
                let lki = l[(k, i)];
                for j in 0..n {
                    let v = y[(k, j)];
                    y[(i, j)] -= lki * v;
                }
            }
            let lii = l[(i, i)];
            for j in 0..n {
                y[(i, j)] /= lii;
            }
        }
        y.symmetrize();
        *a = y;
        Ok(())
    }

    /// Same shared block-reduced pass as the reference backend — the
    /// norm cache was already cache-blocked (PR 1), so there is nothing
    /// further to reorganize on CPU; the seam exists for device
    /// backends.
    fn row_norms(&self, set: &SampleMatrix) -> Result<Vec<f64>> {
        Ok(crate::combine::row_norms(set))
    }

    /// Same panels as the dense op, run straight over the borrowed
    /// block (no temporary matrix). The panel grid restarts at each
    /// chunk boundary, but per-entry accumulation never crosses panels,
    /// so any chunking reproduces `logpdf_table` bit-for-bit — pinned
    /// by the unit test below and the `combine_table_chunked` bench row.
    fn logpdf_table_block(
        &self,
        mvn: &Mvn,
        block: &[f64],
        out: &mut Vec<f64>,
    ) -> Result<()> {
        super::naive::check_block(block, mvn.dim(), "logpdf table")?;
        let d = mvn.dim();
        let width = self.panel_rows;
        let mut panel = vec![0.0f64; d * width];
        let mut acc = vec![0.0f64; width];
        out.reserve(block.len() / d);
        for chunk in block.chunks(d * width) {
            self.table_panel(mvn, chunk, &mut panel, &mut acc, out);
        }
        Ok(())
    }

    /// Shared index-order norm fold (see `naive::norms_block`).
    fn row_norms_block(
        &self,
        block: &[f64],
        dim: usize,
        out: &mut Vec<f64>,
    ) -> Result<()> {
        super::naive::norms_block(block, dim, out)
    }
}

#[cfg(test)]
mod tests {
    use super::super::NaiveKernel;
    use super::*;
    use crate::rng::Pcg64;

    fn random_spd(d: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::seed_from(seed);
        let b = Mat::from_vec(
            (0..d * d).map(|_| rng.normal()).collect(),
            d,
            d,
        )
        .unwrap();
        let mut a = b.matmul(&b.transpose()).unwrap();
        for i in 0..d {
            a[(i, i)] += 0.5;
        }
        a
    }

    fn random_mvn(d: usize, seed: u64) -> Mvn {
        let mut rng = Pcg64::seed_from(seed);
        let mean: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        Mvn::new(mean, random_spd(d, seed ^ 0xA5)).unwrap()
    }

    /// Bit-identity of the table op against the scalar reference, at
    /// panel widths that exercise full panels, remainder panels, and
    /// the degenerate width-1 panel, for several dimensions.
    #[test]
    fn logpdf_table_bit_identical_to_naive() {
        for (d, t, seed) in [(1usize, 7usize, 1u64), (3, 50, 2), (24, 67, 3)] {
            let mvn = random_mvn(d, seed);
            let mut rng = Pcg64::seed_from(seed ^ 0x77);
            let set = mvn.sample_n(t, &mut rng);
            let want = NaiveKernel.logpdf_table(&mvn, &set).unwrap();
            for width in [1usize, 3, 32, 1000] {
                let got = BlockedCpuKernel::with_panel_rows(width)
                    .logpdf_table(&mvn, &set)
                    .unwrap();
                assert_eq!(want.len(), got.len());
                for (t, (w, g)) in want.iter().zip(&got).enumerate() {
                    assert_eq!(
                        w.to_bits(),
                        g.to_bits(),
                        "d={d} width={width} entry {t}: {w} vs {g}"
                    );
                }
            }
        }
    }

    /// Non-finite draws must flow through the blocked panels exactly as
    /// through the scalar path — ∞ − ∞ → NaN in the same places, same
    /// bit patterns (the table feeds IMG weights, where a silent
    /// divergence would corrupt accept decisions).
    #[test]
    fn logpdf_table_preserves_nonfinite_entries_bitwise() {
        let mvn = random_mvn(3, 11);
        let mut rng = Pcg64::seed_from(12);
        let mut set = mvn.sample_n(10, &mut rng);
        set.push(&[f64::INFINITY, 0.5, -0.25]);
        set.push(&[f64::NEG_INFINITY, f64::NAN, 1.0]);
        set.push(&[0.0, -0.0, f64::MAX]);
        let want = NaiveKernel.logpdf_table(&mvn, &set).unwrap();
        let got = BlockedCpuKernel::with_panel_rows(4)
            .logpdf_table(&mvn, &set)
            .unwrap();
        assert!(
            want.iter().any(|v| !v.is_finite()),
            "test must actually produce non-finite table entries"
        );
        for (t, (w, g)) in want.iter().zip(&got).enumerate() {
            assert_eq!(w.to_bits(), g.to_bits(), "entry {t}: {w} vs {g}");
        }
    }

    /// The batched inverse matches the scalar jittered inverse
    /// bit-for-bit, on well-conditioned SPD inputs and on a singular
    /// matrix that takes the jitter-escalation path.
    #[test]
    fn batched_inverse_bit_identical_to_scalar() {
        let singular =
            Mat::from_vec(vec![1.0, 1.0, 1.0, 1.0], 2, 2).unwrap();
        for a in [random_spd(1, 4), random_spd(5, 5), random_spd(24, 6), singular]
        {
            let mut want = a.clone();
            linalg::spd_inverse_jittered_in_place(&mut want).unwrap();
            let mut got = a.clone();
            BlockedCpuKernel::default()
                .spd_inverse_in_place(&mut got)
                .unwrap();
            for (i, (w, g)) in
                want.as_slice().iter().zip(got.as_slice()).enumerate()
            {
                assert_eq!(
                    w.to_bits(),
                    g.to_bits(),
                    "element {i}: {w} vs {g}"
                );
            }
        }
    }

    /// Chunk-streaming the table through `logpdf_table_block` at any
    /// chunk size — aligned or not with the panel width — reproduces
    /// the dense op bit-for-bit. This is the contract that lets the
    /// draw store feed the combine stage without densifying.
    #[test]
    fn table_block_chunking_matches_dense() {
        let mvn = random_mvn(3, 21);
        let mut rng = Pcg64::seed_from(22);
        let set = mvn.sample_n(53, &mut rng);
        let k = BlockedCpuKernel::with_panel_rows(4);
        let want = k.logpdf_table(&mvn, &set).unwrap();
        for rows_per_chunk in [1usize, 7, 32, 1000] {
            let mut got = Vec::new();
            for block in set.rows_chunked(rows_per_chunk) {
                k.logpdf_table_block(&mvn, block, &mut got).unwrap();
            }
            assert_eq!(want.len(), got.len());
            for (t, (w, g)) in want.iter().zip(&got).enumerate() {
                assert_eq!(
                    w.to_bits(),
                    g.to_bits(),
                    "chunk={rows_per_chunk} entry {t}: {w} vs {g}"
                );
            }
        }
        // A ragged block (partial row) is a structured shape error.
        let mut sink = Vec::new();
        assert!(k
            .logpdf_table_block(&mvn, &[1.0, 2.0], &mut sink)
            .is_err());
    }

    /// Same chunking invariance for the norm fold.
    #[test]
    fn norms_block_chunking_matches_dense() {
        let mut rng = Pcg64::seed_from(29);
        let mut set = SampleMatrix::new(3);
        for _ in 0..41 {
            set.push(&[rng.normal(), rng.normal() * 2.0, rng.normal()]);
        }
        let k = BlockedCpuKernel::default();
        let want = k.row_norms(&set).unwrap();
        for rows_per_chunk in [1usize, 7, 64] {
            let mut got = Vec::new();
            for block in set.rows_chunked(rows_per_chunk) {
                k.row_norms_block(block, set.dim(), &mut got).unwrap();
            }
            assert_eq!(want, got, "chunk={rows_per_chunk}");
        }
        let mut sink = Vec::new();
        assert!(k.row_norms_block(&[1.0], 2, &mut sink).is_err());
    }

    #[test]
    fn norms_match_naive() {
        let mut rng = Pcg64::seed_from(9);
        let mut set = SampleMatrix::new(2);
        for _ in 0..77 {
            set.push(&[rng.normal() * 3.0, rng.normal()]);
        }
        let want = NaiveKernel.row_norms(&set).unwrap();
        let got = BlockedCpuKernel::default().row_norms(&set).unwrap();
        assert_eq!(want, got);
    }
}
