//! Scalar reference backend — the combine layer's original loops,
//! extracted behind the [`CombineKernel`] seam.
//!
//! Every other backend is pinned against this one: the blocked CPU
//! kernel must match it bit-for-bit (`rust/tests/kernel_parity.rs`),
//! and the bench gate in `benches/micro_hotpath.rs` measures against
//! it. Keep these bodies boring — they *are* the spec.

use super::CombineKernel;
use crate::error::{Error, Result};
use crate::math::linalg::{self, Mat};
use crate::math::mvn::Mvn;
use crate::types::SampleMatrix;

/// The bit-exact scalar reference backend (`--combine-backend naive`,
/// and the default when no backend is configured).
#[derive(Debug, Clone, Copy, Default)]
pub struct NaiveKernel;

impl CombineKernel for NaiveKernel {
    fn name(&self) -> &'static str {
        "naive"
    }

    /// Row-at-a-time [`Mvn::logpdf_with`] over one reused scratch
    /// buffer — exactly the loop `combine/semiparametric.rs` ran
    /// inline before the kernel seam existed.
    fn logpdf_table(
        &self,
        mvn: &Mvn,
        set: &SampleMatrix,
    ) -> Result<Vec<f64>> {
        check_dims(mvn, set)?;
        let mut scratch = vec![0.0; mvn.dim()];
        Ok(set.rows().map(|r| mvn.logpdf_with(r, &mut scratch)).collect())
    }

    /// Column-at-a-time jittered inverse — the single pre-existing copy
    /// in [`linalg::spd_inverse_jittered_in_place`].
    fn spd_inverse_in_place(&self, a: &mut Mat) -> Result<()> {
        linalg::spd_inverse_jittered_in_place(a)
    }

    /// The combine layer's shared norm pass ([`crate::combine::row_norms`])
    /// — already block-reduced since PR 1; the kernel seam exists so
    /// device backends can take it over, not because the CPU pass needs
    /// restructuring.
    fn row_norms(&self, set: &SampleMatrix) -> Result<Vec<f64>> {
        Ok(crate::combine::row_norms(set))
    }

    /// Same per-row [`Mvn::logpdf_with`] loop as the dense op, run
    /// straight over the borrowed block — no temporary matrix. Each
    /// entry's accumulation is independent of where chunk boundaries
    /// fall, so any chunking reproduces `logpdf_table` bit-for-bit.
    fn logpdf_table_block(
        &self,
        mvn: &Mvn,
        block: &[f64],
        out: &mut Vec<f64>,
    ) -> Result<()> {
        check_block(block, mvn.dim(), "logpdf table")?;
        let mut scratch = vec![0.0; mvn.dim()];
        out.extend(
            block
                .chunks_exact(mvn.dim())
                .map(|r| mvn.logpdf_with(r, &mut scratch)),
        );
        Ok(())
    }

    /// Per-row index-order squared-norm sums over the borrowed block —
    /// the same per-entry fold as [`crate::combine::row_norms`].
    fn row_norms_block(
        &self,
        block: &[f64],
        dim: usize,
        out: &mut Vec<f64>,
    ) -> Result<()> {
        norms_block(block, dim, out)
    }
}

/// Shared input validation for the table op (both CPU backends).
pub(crate) fn check_dims(mvn: &Mvn, set: &SampleMatrix) -> Result<()> {
    if set.dim() != mvn.dim() {
        return Err(Error::Shape(format!(
            "logpdf table: set dim {} != mvn dim {}",
            set.dim(),
            mvn.dim()
        )));
    }
    Ok(())
}

/// Shared whole-rows validation for the chunk-streaming block ops.
pub(crate) fn check_block(block: &[f64], dim: usize, what: &str) -> Result<()> {
    if dim == 0 || block.len() % dim != 0 {
        return Err(Error::Shape(format!(
            "{what} block: {} scalars is not whole rows of dim {dim}",
            block.len()
        )));
    }
    Ok(())
}

/// Per-row squared norms of a flat block, accumulated in index order —
/// the shared body behind both CPU backends' `row_norms_block` (the
/// norm fold has no panel structure worth specializing).
pub(crate) fn norms_block(
    block: &[f64],
    dim: usize,
    out: &mut Vec<f64>,
) -> Result<()> {
    check_block(block, dim, "row norms")?;
    out.extend(
        block
            .chunks_exact(dim)
            .map(|r| r.iter().map(|v| v * v).sum::<f64>()),
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn table_matches_per_row_logpdf() {
        let mut rng = Pcg64::seed_from(3);
        let cov = Mat::from_vec(vec![2.0, 0.7, 0.7, 1.5], 2, 2).unwrap();
        let mvn = Mvn::new(vec![0.4, -0.2], cov).unwrap();
        let set = mvn.sample_n(37, &mut rng);
        let table = NaiveKernel.logpdf_table(&mvn, &set).unwrap();
        assert_eq!(table.len(), 37);
        for (t, row) in set.rows().enumerate() {
            assert_eq!(table[t].to_bits(), mvn.logpdf(row).to_bits());
        }
    }

    #[test]
    fn table_rejects_dim_mismatch() {
        let mvn = Mvn::new(vec![0.0; 3], Mat::identity(3)).unwrap();
        let set = SampleMatrix::from_rows(vec![1.0, 2.0], 2).unwrap();
        assert!(NaiveKernel.logpdf_table(&mvn, &set).is_err());
    }

    #[test]
    fn norms_match_reference_pass() {
        let mut rng = Pcg64::seed_from(5);
        let mut set = SampleMatrix::new(3);
        for _ in 0..130 {
            set.push(&[rng.normal(), rng.normal(), rng.normal()]);
        }
        let got = NaiveKernel.row_norms(&set).unwrap();
        for (row, n) in set.rows().zip(&got) {
            let want: f64 = row.iter().map(|v| v * v).sum();
            assert_eq!(want.to_bits(), n.to_bits());
        }
    }
}
