//! Bayesian logistic regression (paper section 8.1).
//!
//! `y_i ~ Bernoulli(logit⁻¹(x_i·β))` with a powered `N(0, I/prior_prec)`
//! prior on β. This is the native-backend mirror of the L1 Pallas kernel
//! (`python/compile/kernels/logistic.py`) + L2 prior, with identical
//! softplus stabilization.

use super::{powered_gauss_prior, LogDensity};
use crate::math::special::{log1p_exp, sigmoid};
use crate::types::SampleMatrix;

/// Logistic regression likelihood over a data shard.
#[derive(Debug, Clone)]
pub struct LogisticRegression {
    /// n × d design matrix.
    x: SampleMatrix,
    /// n labels in {0, 1}.
    y: Vec<f64>,
    pub prior_prec: f64,
    pub prior_w: f64,
}

impl LogisticRegression {
    pub fn new(
        x: SampleMatrix,
        y: Vec<f64>,
        prior_prec: f64,
        prior_w: f64,
    ) -> Self {
        assert_eq!(x.len(), y.len(), "x/y row mismatch");
        assert!(prior_prec > 0.0 && prior_w > 0.0);
        LogisticRegression { x, y, prior_prec, prior_w }
    }

    pub fn n(&self) -> usize {
        self.x.len()
    }

    pub fn data(&self) -> (&SampleMatrix, &[f64]) {
        (&self.x, &self.y)
    }

    /// Posterior-predictive probability `P(y=1 | x)` averaged over draws.
    pub fn predictive_prob(samples: &SampleMatrix, x: &[f64]) -> f64 {
        let mut acc = 0.0;
        for beta in samples.rows() {
            acc += sigmoid(crate::math::linalg::dot(x, beta));
        }
        acc / samples.len() as f64
    }
}

impl LogDensity for LogisticRegression {
    fn dim(&self) -> usize {
        self.x.dim()
    }

    fn logp_grad(&self, theta: &[f64]) -> (f64, Vec<f64>) {
        let d = self.x.dim();
        let mut ll = 0.0;
        let mut grad = vec![0.0; d];
        for (row, &yi) in self.x.rows().zip(&self.y) {
            let z = crate::math::linalg::dot(row, theta);
            ll += yi * z - log1p_exp(z);
            let r = yi - sigmoid(z);
            crate::math::linalg::axpy(r, row, &mut grad);
        }
        let lp = powered_gauss_prior(theta, self.prior_w, self.prior_prec, &mut grad);
        (ll + lp, grad)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn toy(seed: u64, n: usize, d: usize) -> LogisticRegression {
        let mut rng = Pcg64::seed_from(seed);
        let beta: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let mut x = SampleMatrix::new(d);
        let mut y = Vec::new();
        for _ in 0..n {
            let row: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
            let p = sigmoid(crate::math::linalg::dot(&row, &beta));
            y.push(if rng.bernoulli(p) { 1.0 } else { 0.0 });
            x.push(&row);
        }
        LogisticRegression::new(x, y, 1.0, 0.1)
    }

    #[test]
    fn grad_matches_finite_diff() {
        let m = toy(1, 40, 4);
        let theta = [0.2, -0.5, 0.1, 0.7];
        let (_, g) = m.logp_grad(&theta);
        let eps = 1e-6;
        for j in 0..4 {
            let mut tp = theta;
            tp[j] += eps;
            let mut tm = theta;
            tm[j] -= eps;
            let fd = (m.logp(&tp) - m.logp(&tm)) / (2.0 * eps);
            assert!((g[j] - fd).abs() < 1e-4, "dim {j}");
        }
    }

    #[test]
    fn extreme_logits_stay_finite() {
        let mut x = SampleMatrix::new(2);
        x.push(&[100.0, -100.0]);
        x.push(&[-100.0, 100.0]);
        let m = LogisticRegression::new(x, vec![1.0, 0.0], 1.0, 1.0);
        let (lp, g) = m.logp_grad(&[3.0, -3.0]);
        assert!(lp.is_finite());
        assert!(g.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn perfect_separation_pulled_back_by_prior() {
        // One positive at +1, one negative at -1: likelihood alone pushes
        // β → ∞; the prior must keep the mode finite.
        let mut x = SampleMatrix::new(1);
        x.push(&[1.0]);
        x.push(&[-1.0]);
        let m = LogisticRegression::new(x, vec![1.0, 0.0], 1.0, 1.0);
        // logp must eventually decrease in β.
        assert!(m.logp(&[50.0]) < m.logp(&[2.0]));
    }

    #[test]
    fn predictive_prob_bounds() {
        let m = toy(2, 30, 3);
        let mut rng = Pcg64::seed_from(3);
        let mut draws = SampleMatrix::new(3);
        for _ in 0..20 {
            draws.push(&[rng.normal(), rng.normal(), rng.normal()]);
        }
        let p = LogisticRegression::predictive_prob(&draws, &[0.5, 0.5, 0.5]);
        assert!((0.0..=1.0).contains(&p));
        let _ = m; // silence unused in this test
    }
}
