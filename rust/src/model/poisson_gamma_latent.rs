//! Poisson-gamma hierarchical model with EXPLICIT latent rates
//! (paper section 8.3, as written — no marginalization).
//!
//! `a ~ Exp(λ)`, `b ~ Gamma(α, β)`, `q_i ~ Gamma(a, b)`,
//! `x_i ~ Poisson(q_i t_i)`. [`crate::model::PoissonGamma`] integrates
//! the `q_i` out analytically; this variant keeps them and is sampled
//! with the blocked Gibbs kernel in [`crate::sampler::gibbs`]:
//!
//!   q_i | a, b, x  ~  Gamma(a + x_i, b + t_i)        (conjugate)
//!   a, b | q       via random-walk MH on (log a, log b)
//!
//! It exists to exercise the paper's criterion (3): each machine may run
//! *any* MCMC method — here a model-specific Gibbs sampler — and the
//! combination stage is agnostic to it. Only (log a, log b) is reported
//! to the leader; the latents stay on the machine (criterion 1).

use crate::math::special::lgamma;
use crate::rng::Pcg64;

/// Poisson-gamma with latent rates; state is (log a, log b, q_1..q_n)
/// but only the 2-d hyperparameter block is exposed to the coordinator.
#[derive(Debug, Clone)]
pub struct PoissonGammaLatent {
    pub xs: Vec<f64>,
    pub ts: Vec<f64>,
    pub prior_w: f64,
    pub lam: f64,
    pub alpha: f64,
    pub beta_p: f64,
}

impl PoissonGammaLatent {
    pub fn new(
        xs: Vec<f64>,
        ts: Vec<f64>,
        prior_w: f64,
        lam: f64,
        alpha: f64,
        beta_p: f64,
    ) -> Self {
        assert_eq!(xs.len(), ts.len());
        assert!(lam > 0.0 && alpha > 0.0 && beta_p > 0.0 && prior_w > 0.0);
        PoissonGammaLatent { xs, ts, prior_w, lam, alpha, beta_p }
    }

    pub fn n(&self) -> usize {
        self.xs.len()
    }

    /// Conjugate update: redraw all q_i | a, b.
    pub fn resample_latents(
        &self,
        log_a: f64,
        log_b: f64,
        q: &mut [f64],
        rng: &mut Pcg64,
    ) {
        let a = log_a.exp();
        let b = log_b.exp();
        for ((qi, &x), &t) in q.iter_mut().zip(&self.xs).zip(&self.ts) {
            *qi = rng.gamma(a + x, b + t).max(1e-300);
        }
    }

    /// log p(log a, log b | q): the hyperparameter conditional, up to a
    /// constant (Gamma likelihood of the q_i + powered priors +
    /// log-transform Jacobian).
    pub fn hyper_logp(&self, log_a: f64, log_b: f64, q: &[f64]) -> f64 {
        let a = log_a.exp();
        let b = log_b.exp();
        let n = q.len() as f64;
        let sum_log_q: f64 = q.iter().map(|v| v.ln()).sum();
        let sum_q: f64 = q.iter().sum();
        // Π Gamma(q_i; a, b) = b^{na} Γ(a)^{-n} (Π q_i)^{a-1} e^{-b Σ q_i}
        let ll = n * a * b.ln() - n * lgamma(a) + (a - 1.0) * sum_log_q
            - b * sum_q;
        let lp_a = self.lam.ln() - self.lam * a;
        let lp_b = self.alpha * self.beta_p.ln() - lgamma(self.alpha)
            + (self.alpha - 1.0) * b.ln()
            - self.beta_p * b;
        ll + self.prior_w * (lp_a + lp_b) + log_a + log_b
    }

    /// A moment-matched initial (log a, log b, q).
    pub fn init(&self, rng: &mut Pcg64) -> (f64, f64, Vec<f64>) {
        let log_a = 0.1 * rng.normal();
        let log_b = 0.1 * rng.normal();
        let mut q = vec![1.0; self.n()];
        self.resample_latents(log_a, log_b, &mut q, rng);
        (log_a, log_b, q)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy(seed: u64, n: usize) -> PoissonGammaLatent {
        let mut rng = Pcg64::seed_from(seed);
        let (a, b) = (2.0, 1.5);
        let mut xs = Vec::new();
        let mut ts = Vec::new();
        for _ in 0..n {
            let t = 0.5 + rng.uniform();
            let q = rng.gamma(a, b);
            xs.push(rng.poisson(q * t) as f64);
            ts.push(t);
        }
        PoissonGammaLatent::new(xs, ts, 1.0, 1.0, 2.0, 1.0)
    }

    #[test]
    fn latent_conditional_moments() {
        // q_i | a,b,x_i ~ Gamma(a+x, b+t): empirical mean must match.
        let m = toy(1, 1);
        let (x, t) = (m.xs[0], m.ts[0]);
        let (a, b) = (2.0f64, 1.5f64);
        let mut rng = Pcg64::seed_from(2);
        let mut q = vec![1.0];
        let mut acc = 0.0;
        let reps = 20_000;
        for _ in 0..reps {
            m.resample_latents(a.ln(), b.ln(), &mut q, &mut rng);
            acc += q[0];
        }
        let want = (a + x) / (b + t);
        let got = acc / reps as f64;
        assert!((got - want).abs() < 0.05 * want.max(0.2), "{got} vs {want}");
    }

    #[test]
    fn hyper_logp_peaks_near_truth_given_true_latents() {
        let m = toy(3, 2_000);
        let mut rng = Pcg64::seed_from(4);
        // Draw latents from the true conditional at the true (a,b).
        let mut q = vec![1.0; m.n()];
        m.resample_latents(2.0f64.ln(), 1.5f64.ln(), &mut q, &mut rng);
        let at_truth = m.hyper_logp(2.0f64.ln(), 1.5f64.ln(), &q);
        let off = m.hyper_logp(0.0, 0.0, &q);
        assert!(at_truth > off, "{at_truth} vs {off}");
    }

    #[test]
    fn marginalized_and_latent_models_agree_in_distribution() {
        // The marginal p(a, b | x) is identical whether q is integrated
        // analytically or by Monte Carlo over the conditional. Check via
        // Rao-Blackwell: E_q[hyper_logp] tracks the marginal logp up to
        // a θ-independent constant (compare differences between two θ).
        let m_lat = toy(5, 800);
        let m_marg = crate::model::PoissonGamma::new(
            m_lat.xs.clone(),
            m_lat.ts.clone(),
            1.0,
            1.0,
            2.0,
            1.0,
        );
        use crate::model::LogDensity;
        let th1 = [2.0f64.ln(), 1.5f64.ln()];
        let th2 = [0.4, 0.1];
        let marg_diff = m_marg.logp(&th1) - m_marg.logp(&th2);
        // MC estimate of the latent model's marginal via importance of
        // the conditional at each θ: log p(θ|x) ∝ log E_q|θ[…] — here we
        // use a crude bridge: average hyper_logp under latents drawn at
        // that same θ plus the entropy term cancels in expectation over
        // many draws; we only check the SIGN and rough scale.
        let mut rng = Pcg64::seed_from(6);
        let mut q = vec![1.0; m_lat.n()];
        let mut avg1 = 0.0;
        let mut avg2 = 0.0;
        let reps = 60;
        for _ in 0..reps {
            m_lat.resample_latents(th1[0], th1[1], &mut q, &mut rng);
            avg1 += m_lat.hyper_logp(th1[0], th1[1], &q) / reps as f64;
            m_lat.resample_latents(th2[0], th2[1], &mut q, &mut rng);
            avg2 += m_lat.hyper_logp(th2[0], th2[1], &q) / reps as f64;
        }
        // Both orderings must agree (θ1 is the truth, so both positive).
        assert_eq!(marg_diff > 0.0, avg1 - avg2 > 0.0);
    }
}
