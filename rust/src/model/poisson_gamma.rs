//! Hierarchical Poisson-gamma model (paper section 8.3).
//!
//! `a ~ Exp(λ)`, `b ~ Gamma(α, β)`, `q_i ~ Gamma(a, b)`,
//! `x_i ~ Poisson(q_i t_i)`. The rates `q_i` are marginalized
//! analytically (negative-binomial likelihood), leaving the unconstrained
//! parameter `θ = (log a, log b) ∈ ℝ²` — the paper's method requires
//! real, unconstrained θ (section 6). The log transform contributes the
//! Jacobian `log a + log b`.

use super::LogDensity;
use crate::math::special::{digamma, lgamma};

/// Marginalized Poisson-gamma subposterior over (log a, log b).
#[derive(Debug, Clone)]
pub struct PoissonGamma {
    /// Observed counts.
    xs: Vec<f64>,
    /// Exposures t_i.
    ts: Vec<f64>,
    pub prior_w: f64,
    /// Exp(λ) prior rate for a.
    pub lam: f64,
    /// Gamma(α, β) prior for b.
    pub alpha: f64,
    pub beta_p: f64,
}

impl PoissonGamma {
    pub fn new(
        xs: Vec<f64>,
        ts: Vec<f64>,
        prior_w: f64,
        lam: f64,
        alpha: f64,
        beta_p: f64,
    ) -> Self {
        assert_eq!(xs.len(), ts.len());
        assert!(lam > 0.0 && alpha > 0.0 && beta_p > 0.0 && prior_w > 0.0);
        PoissonGamma { xs, ts, prior_w, lam, alpha, beta_p }
    }

    pub fn n(&self) -> usize {
        self.xs.len()
    }

    pub fn data(&self) -> (&[f64], &[f64]) {
        (&self.xs, &self.ts)
    }
}

impl LogDensity for PoissonGamma {
    fn dim(&self) -> usize {
        2
    }

    fn logp_grad(&self, theta: &[f64]) -> (f64, Vec<f64>) {
        let (log_a, log_b) = (theta[0], theta[1]);
        let a = log_a.exp();
        let b = log_b.exp();
        let mut ll = 0.0;
        let mut dll_da = 0.0;
        let mut dll_db = 0.0;
        for (&x, &t) in self.xs.iter().zip(&self.ts) {
            let log_bt = (b + t).ln();
            ll += lgamma(x + a) - lgamma(a) - lgamma(x + 1.0)
                + a * (b.ln() - log_bt)
                + x * (t.ln() - log_bt);
            dll_da += digamma(x + a) - digamma(a) + b.ln() - log_bt;
            dll_db += a / b - (a + x) / (b + t);
        }
        // Powered priors.
        let lp_a = self.lam.ln() - self.lam * a;
        let lp_b = self.alpha * self.beta_p.ln() - lgamma(self.alpha)
            + (self.alpha - 1.0) * b.ln()
            - self.beta_p * b;
        let dpr_da = -self.lam;
        let dpr_db = (self.alpha - 1.0) / b - self.beta_p;
        // Jacobian of the log transform: + log a + log b.
        let lp = ll + self.prior_w * (lp_a + lp_b) + log_a + log_b;
        // Chain rule to (log a, log b): d/d log a = a · d/da, plus the
        // Jacobian's contribution of +1 to each.
        let g0 = a * (dll_da + self.prior_w * dpr_da) + 1.0;
        let g1 = b * (dll_db + self.prior_w * dpr_db) + 1.0;
        (lp, vec![g0, g1])
    }

    fn init_point(&self, rng: &mut crate::rng::Pcg64) -> Vec<f64> {
        // Moment-matched start: mean of x/t ≈ a/b.
        let mean_rate = self
            .xs
            .iter()
            .zip(&self.ts)
            .map(|(x, t)| x / t.max(1e-9))
            .sum::<f64>()
            / self.xs.len().max(1) as f64;
        let a0: f64 = 1.0 + 0.1 * rng.normal();
        let b0 = (a0 / mean_rate.max(1e-3)).max(1e-3);
        vec![a0.max(0.1).ln(), b0.ln()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn toy(seed: u64, n: usize) -> PoissonGamma {
        let mut rng = Pcg64::seed_from(seed);
        let (a, b) = (2.0, 1.5);
        let mut xs = Vec::new();
        let mut ts = Vec::new();
        for _ in 0..n {
            let t = 0.5 + rng.uniform();
            let q = rng.gamma(a, b);
            xs.push(rng.poisson(q * t) as f64);
            ts.push(t);
        }
        PoissonGamma::new(xs, ts, 0.1, 1.0, 2.0, 1.0)
    }

    #[test]
    fn grad_matches_finite_diff() {
        let m = toy(1, 60);
        let theta = [0.4, -0.3];
        let (_, g) = m.logp_grad(&theta);
        let eps = 1e-6;
        for j in 0..2 {
            let mut tp = theta;
            tp[j] += eps;
            let mut tm = theta;
            tm[j] -= eps;
            let fd = (m.logp(&tp) - m.logp(&tm)) / (2.0 * eps);
            assert!(
                (g[j] - fd).abs() < 1e-3 * fd.abs().max(1.0),
                "dim {j}: {} vs {fd}",
                g[j]
            );
        }
    }

    #[test]
    fn logp_finite_over_plausible_range() {
        let m = toy(2, 40);
        for &la in &[-2.0, 0.0, 1.5] {
            for &lb in &[-2.0, 0.0, 1.5] {
                let (lp, g) = m.logp_grad(&[la, lb]);
                assert!(lp.is_finite(), "({la},{lb})");
                assert!(g.iter().all(|v| v.is_finite()));
            }
        }
    }

    #[test]
    fn mode_near_true_parameters() {
        // With lots of data, the MAP of (log a, log b) should be near the
        // generating values (2.0, 1.5) → (ln 2, ln 1.5).
        let m = toy(3, 5000);
        // Gradient ascent (crude but deterministic).
        let mut th = vec![0.0, 0.0];
        for _ in 0..4000 {
            let (_, g) = m.logp_grad(&th);
            th[0] += 1e-5 * g[0];
            th[1] += 1e-5 * g[1];
        }
        assert!((th[0] - 2.0f64.ln()).abs() < 0.25, "log a {}", th[0]);
        assert!((th[1] - 1.5f64.ln()).abs() < 0.25, "log b {}", th[1]);
    }

    #[test]
    fn init_point_is_finite() {
        let m = toy(4, 30);
        let mut rng = Pcg64::seed_from(5);
        let p = m.init_point(&mut rng);
        assert_eq!(p.len(), 2);
        assert!(p.iter().all(|v| v.is_finite()));
    }
}
