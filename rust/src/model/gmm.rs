//! Gaussian mixture model over component means (paper section 8.2).
//!
//! `x_i ~ Σ_k π_k N(μ_k, σ² I_dim)` with known weights π and known σ².
//! θ is the flattened (K × dim) mean matrix; the posterior is multimodal
//! because any permutation of the component labels has equal density.
//! [`LogDensity::symmetry_move`] applies such a permutation — the paper
//! permutes labels before each MH step to force the sampler to visit all
//! K! modes of each mean's marginal.

use super::{powered_gauss_prior, LogDensity};
use crate::math::special::log_sum_exp;
use crate::rng::Pcg64;
use crate::types::SampleMatrix;

const LOG_2PI: f64 = 1.837_877_066_409_345_5;

/// GMM with unknown means, known weights and isotropic variance.
#[derive(Debug, Clone)]
pub struct GmmMeans {
    /// n × dim data shard.
    x: SampleMatrix,
    /// Log mixture weights (length K).
    pub logw: Vec<f64>,
    /// 1/σ².
    pub inv_var: f64,
    pub prior_prec: f64,
    pub prior_w: f64,
    /// Probability of applying a label permutation before an MCMC step.
    pub permute_prob: f64,
}

impl GmmMeans {
    pub fn new(
        x: SampleMatrix,
        logw: Vec<f64>,
        inv_var: f64,
        prior_prec: f64,
        prior_w: f64,
    ) -> Self {
        assert!(inv_var > 0.0 && prior_prec > 0.0 && prior_w > 0.0);
        assert!(!logw.is_empty());
        GmmMeans {
            x,
            logw,
            inv_var,
            prior_prec,
            prior_w,
            permute_prob: 1.0,
        }
    }

    pub fn n_components(&self) -> usize {
        self.logw.len()
    }

    pub fn data_dim(&self) -> usize {
        self.x.dim()
    }

    pub fn n(&self) -> usize {
        self.x.len()
    }
}

impl LogDensity for GmmMeans {
    fn dim(&self) -> usize {
        self.logw.len() * self.x.dim()
    }

    fn logp_grad(&self, theta: &[f64]) -> (f64, Vec<f64>) {
        let k = self.logw.len();
        let dim = self.x.dim();
        assert_eq!(theta.len(), k * dim);
        let log_norm =
            0.5 * dim as f64 * (LOG_2PI - self.inv_var.ln());
        let mut ll = 0.0;
        let mut grad = vec![0.0; k * dim];
        let mut z = vec![0.0; k];
        for row in self.x.rows() {
            for c in 0..k {
                let mu = &theta[c * dim..(c + 1) * dim];
                let sq = crate::math::linalg::sq_dist(row, mu);
                z[c] = self.logw[c] - 0.5 * self.inv_var * sq - log_norm;
            }
            let lse = log_sum_exp(&z);
            ll += lse;
            for c in 0..k {
                let r = (z[c] - lse).exp(); // responsibility
                let mu = &theta[c * dim..(c + 1) * dim];
                let g = &mut grad[c * dim..(c + 1) * dim];
                for j in 0..dim {
                    g[j] += self.inv_var * r * (row[j] - mu[j]);
                }
            }
        }
        let lp = powered_gauss_prior(theta, self.prior_w, self.prior_prec, &mut grad);
        (ll + lp, grad)
    }

    fn init_point(&self, rng: &mut Pcg64) -> Vec<f64> {
        // Scatter initial means around random data points.
        let k = self.logw.len();
        let dim = self.x.dim();
        let mut theta = vec![0.0; k * dim];
        for c in 0..k {
            let row = self.x.row(rng.uniform_usize(self.x.len().max(1)));
            for j in 0..dim {
                theta[c * dim + j] = row[j] + 0.1 * rng.normal();
            }
        }
        theta
    }

    /// Random label permutation — leaves the posterior invariant.
    fn symmetry_move(&self, theta: &mut [f64], rng: &mut Pcg64) {
        if !rng.bernoulli(self.permute_prob) {
            return;
        }
        let k = self.logw.len();
        let dim = self.x.dim();
        // Only exchangeable (equal-weight) blocks may be permuted.
        let w0 = self.logw[0];
        if self.logw.iter().any(|&w| (w - w0).abs() > 1e-12) {
            return;
        }
        let perm = rng.permutation(k);
        let old = theta.to_vec();
        for (c, &p) in perm.iter().enumerate() {
            theta[c * dim..(c + 1) * dim]
                .copy_from_slice(&old[p * dim..(p + 1) * dim]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy(seed: u64, n: usize, k: usize, dim: usize) -> GmmMeans {
        let mut rng = Pcg64::seed_from(seed);
        let mut x = SampleMatrix::new(dim);
        for _ in 0..n {
            let c = rng.uniform_usize(k);
            let row: Vec<f64> =
                (0..dim).map(|j| 3.0 * (c + j) as f64 + rng.normal()).collect();
            x.push(&row);
        }
        let logw = vec![-(k as f64).ln(); k];
        GmmMeans::new(x, logw, 1.0, 0.1, 0.2)
    }

    #[test]
    fn grad_matches_finite_diff() {
        let m = toy(1, 30, 3, 2);
        let mut rng = Pcg64::seed_from(2);
        let theta = m.init_point(&mut rng);
        let (_, g) = m.logp_grad(&theta);
        let eps = 1e-6;
        for j in 0..theta.len() {
            let mut tp = theta.clone();
            tp[j] += eps;
            let mut tm = theta.clone();
            tm[j] -= eps;
            let fd = (m.logp(&tp) - m.logp(&tm)) / (2.0 * eps);
            assert!((g[j] - fd).abs() < 1e-4, "dim {j}: {} vs {fd}", g[j]);
        }
    }

    #[test]
    fn permutation_leaves_logp_invariant() {
        let m = toy(3, 40, 4, 2);
        let mut rng = Pcg64::seed_from(5);
        let theta = m.init_point(&mut rng);
        let lp = m.logp(&theta);
        let mut permuted = theta.clone();
        m.symmetry_move(&mut permuted, &mut rng);
        assert!((m.logp(&permuted) - lp).abs() < 1e-9);
    }

    #[test]
    fn unequal_weights_block_permutation() {
        let mut m = toy(7, 20, 2, 2);
        m.logw = vec![(0.7f64).ln(), (0.3f64).ln()];
        let mut rng = Pcg64::seed_from(8);
        let theta = vec![1.0, 2.0, 3.0, 4.0];
        let mut t = theta.clone();
        for _ in 0..20 {
            m.symmetry_move(&mut t, &mut rng);
        }
        assert_eq!(t, theta, "permutation must be skipped for unequal weights");
    }

    #[test]
    fn single_component_equals_gaussian_loglik() {
        let mut x = SampleMatrix::new(2);
        x.push(&[1.0, 0.0]);
        x.push(&[0.0, 1.0]);
        let m = GmmMeans::new(x.clone(), vec![0.0], 2.0, 1.0, 1e-12);
        let theta = [0.25, -0.5];
        let (lp, _) = m.logp_grad(&theta);
        // Manual: Σ log N(x_i | θ, I/2).
        let mut want = 0.0;
        for row in x.rows() {
            want += crate::math::mvn::iso_logpdf(row, &theta, 0.5);
        }
        assert!((lp - want).abs() < 1e-6, "{lp} vs {want}");
    }
}
