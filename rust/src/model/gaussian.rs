//! Conjugate Gaussian mean model — the exactness anchor.
//!
//! `x_i ~ N(θ, I/lik_prec)`, `θ ~ N(0, I/prior_prec)`. Both the
//! subposterior and the full posterior are Gaussian in closed form, so
//! the combination algorithms can be verified *exactly* (DESIGN.md §6).

use super::{powered_gauss_prior, LogDensity};
use crate::math::linalg::Mat;
use crate::math::mvn::Mvn;
use crate::types::SampleMatrix;

const LOG_2PI: f64 = 1.837_877_066_409_345_5;

/// Gaussian likelihood with unknown mean and known isotropic precision.
#[derive(Debug, Clone)]
pub struct GaussianMean {
    /// Data shard, one observation per row (n × d).
    data: SampleMatrix,
    /// Known likelihood precision (1/σ²).
    pub lik_prec: f64,
    /// Prior precision τ (prior is N(0, I/τ)).
    pub prior_prec: f64,
    /// Prior weight 1/M (Eq. 2.1).
    pub prior_w: f64,
    /// Cached Σ_i x_i.
    sum_x: Vec<f64>,
}

impl GaussianMean {
    pub fn new(
        data: SampleMatrix,
        lik_prec: f64,
        prior_prec: f64,
        prior_w: f64,
    ) -> Self {
        assert!(lik_prec > 0.0 && prior_prec > 0.0 && prior_w > 0.0);
        let d = data.dim();
        let mut sum_x = vec![0.0; d];
        for row in data.rows() {
            for j in 0..d {
                sum_x[j] += row[j];
            }
        }
        GaussianMean { data, lik_prec, prior_prec, prior_w, sum_x }
    }

    pub fn n(&self) -> usize {
        self.data.len()
    }

    /// Closed-form subposterior `N(μ*, Σ*)`:
    /// precision `P = n·lik_prec + prior_w·prior_prec`,
    /// mean `μ* = lik_prec · Σx / P`.
    pub fn exact_posterior(&self) -> Mvn {
        let d = self.data.dim();
        let n = self.data.len() as f64;
        let prec = n * self.lik_prec + self.prior_w * self.prior_prec;
        let mean: Vec<f64> =
            self.sum_x.iter().map(|s| self.lik_prec * s / prec).collect();
        Mvn::new(mean, Mat::scaled_identity(d, 1.0 / prec)).unwrap()
    }
}

impl LogDensity for GaussianMean {
    fn dim(&self) -> usize {
        self.data.dim()
    }

    fn logp_grad(&self, theta: &[f64]) -> (f64, Vec<f64>) {
        let d = self.data.dim();
        let n = self.data.len() as f64;
        // Likelihood: -lik_prec/2 Σ|x_i - θ|² + (nd/2)(log lik_prec - log 2π).
        // Use Σ|x_i - θ|² = Σ|x_i|² - 2θ·Σx + n|θ|² — O(d) per call after
        // caching (the data pass happens once in `new`).
        let mut sq = 0.0;
        for row in self.data.rows() {
            for (xi, ti) in row.iter().zip(theta) {
                let r = xi - ti;
                sq += r * r;
            }
        }
        let ll = -0.5 * self.lik_prec * sq
            + 0.5 * n * d as f64 * (self.lik_prec.ln() - LOG_2PI);
        let mut grad = vec![0.0; d];
        for j in 0..d {
            grad[j] = self.lik_prec * (self.sum_x[j] - n * theta[j]);
        }
        let lp = powered_gauss_prior(theta, self.prior_w, self.prior_prec, &mut grad);
        (ll + lp, grad)
    }

    fn init_point(&self, _rng: &mut crate::rng::Pcg64) -> Vec<f64> {
        // Start at the data mean — cheap and in the typical set.
        let n = self.data.len().max(1) as f64;
        self.sum_x.iter().map(|s| s / n).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn toy(seed: u64, n: usize, d: usize) -> GaussianMean {
        let mut rng = Pcg64::seed_from(seed);
        let mut s = SampleMatrix::new(d);
        for _ in 0..n {
            let row: Vec<f64> = (0..d).map(|_| rng.normal() + 1.5).collect();
            s.push(&row);
        }
        GaussianMean::new(s, 1.0, 0.5, 0.25)
    }

    #[test]
    fn grad_matches_finite_diff() {
        let m = toy(1, 50, 3);
        let theta = [0.3, -0.2, 0.9];
        let (_, g) = m.logp_grad(&theta);
        let eps = 1e-6;
        for j in 0..3 {
            let mut tp = theta;
            tp[j] += eps;
            let mut tm = theta;
            tm[j] -= eps;
            let fd = (m.logp(&tp) - m.logp(&tm)) / (2.0 * eps);
            assert!((g[j] - fd).abs() < 1e-4, "dim {j}: {} vs {fd}", g[j]);
        }
    }

    #[test]
    fn mode_matches_exact_posterior_mean() {
        let m = toy(2, 100, 2);
        let post = m.exact_posterior();
        // ∇ log p = 0 at the posterior mean.
        let (_, g) = m.logp_grad(post.mean());
        assert!(g.iter().all(|v| v.abs() < 1e-8), "grad at mode {g:?}");
    }

    #[test]
    fn logp_shape_is_quadratic_around_mode() {
        let m = toy(3, 80, 2);
        let post = m.exact_posterior();
        let mu = post.mean().to_vec();
        let lp0 = m.logp(&mu);
        let off: Vec<f64> = mu.iter().map(|v| v + 0.1).collect();
        assert!(m.logp(&off) < lp0);
    }

    #[test]
    fn prior_weight_unity_recovers_full_prior() {
        // logp(w=1) - logp(w≈0) equals the full prior logpdf.
        let mut rng = Pcg64::seed_from(4);
        let mut s = SampleMatrix::new(2);
        for _ in 0..10 {
            s.push(&[rng.normal(), rng.normal()]);
        }
        let theta = [0.4, -1.0];
        let m1 = GaussianMean::new(s.clone(), 1.0, 2.0, 1.0);
        let m0 = GaussianMean::new(s, 1.0, 2.0, 1e-12);
        let prior = crate::math::mvn::Mvn::new(
            vec![0.0, 0.0],
            Mat::scaled_identity(2, 0.5),
        )
        .unwrap();
        let diff = m1.logp(&theta) - m0.logp(&theta);
        assert!((diff - prior.logpdf(&theta)).abs() < 1e-6);
    }
}
