//! Target densities: the `LogDensity` trait and the paper's test models.
//!
//! Every model evaluates the **subposterior** of Eq. 2.1,
//! `log p_m(θ) = prior_w · log p(θ) + log p(x^{n_m} | θ)`, where
//! `prior_w = 1/M` and `prior_w = 1` recovers the full-data posterior.
//! The rust implementations here are the *native backend*: they mirror
//! the JAX L2 graphs bit-for-bit in structure (same constants, same
//! stabilizations) so `runtime::native` and the PJRT artifacts are
//! interchangeable — integration tests assert parity.

pub mod gaussian;
pub mod gmm;
pub mod linreg;
pub mod logistic;
pub mod poisson_gamma;
pub mod poisson_gamma_latent;

pub use gaussian::GaussianMean;
pub use gmm::GmmMeans;
pub use linreg::LinearRegression;
pub use logistic::LogisticRegression;
pub use poisson_gamma::PoissonGamma;
pub use poisson_gamma_latent::PoissonGammaLatent;

use crate::rng::Pcg64;

/// A differentiable (sub)posterior log-density over θ ∈ ℝᵈ.
///
/// Deliberately *not* `Send`/`Sync`: the PJRT-backed implementation
/// ([`crate::runtime::XlaDensity`]) holds thread-local client handles.
/// The threaded pipeline constructs native models inside each worker
/// thread instead of sharing them.
pub trait LogDensity {
    /// Dimensionality of θ.
    fn dim(&self) -> usize;

    /// Log density (up to the same constant as the AOT artifact).
    fn logp(&self, theta: &[f64]) -> f64 {
        self.logp_grad(theta).0
    }

    /// Log density and gradient.
    fn logp_grad(&self, theta: &[f64]) -> (f64, Vec<f64>);

    /// A cheap, rough initial point for chains.
    fn init_point(&self, rng: &mut Pcg64) -> Vec<f64> {
        (0..self.dim()).map(|_| 0.1 * rng.normal()).collect()
    }

    /// Apply a posterior-invariant symmetry move in place (e.g. label
    /// permutation for mixture models — paper section 8.2). Default: none.
    fn symmetry_move(&self, _theta: &mut [f64], _rng: &mut Pcg64) {}

    /// Optional fused leapfrog trajectory: advance `n_steps` HMC leapfrog
    /// steps in a single evaluation. The PJRT runtime backend implements
    /// this with one artifact execution (the L2 perf optimization);
    /// native models return `None` and the sampler falls back to
    /// step-by-step leapfrog over [`LogDensity::logp_grad`].
    fn fused_trajectory(
        &self,
        _theta: &[f64],
        _p: &[f64],
        _eps: f64,
        _n_steps: usize,
    ) -> Option<Trajectory> {
        None
    }
}

/// Result of an HMC leapfrog trajectory.
#[derive(Debug, Clone)]
pub struct Trajectory {
    pub theta: Vec<f64>,
    pub p: Vec<f64>,
    pub logp: f64,
    pub grad: Vec<f64>,
    /// Log-density at the trajectory start (for the MH ratio).
    pub logp0: f64,
}

/// Shared powered-Gaussian prior: `prior_w · log N(θ | 0, I/prior_prec)`
/// including the normalization constant (so artifacts and native agree on
/// absolute values), plus its gradient contribution.
pub(crate) fn powered_gauss_prior(
    theta: &[f64],
    prior_w: f64,
    prior_prec: f64,
    grad: &mut [f64],
) -> f64 {
    const LOG_2PI: f64 = 1.837_877_066_409_345_5;
    let d = theta.len() as f64;
    let sq: f64 = theta.iter().map(|t| t * t).sum();
    let lp = -0.5 * prior_prec * sq + 0.5 * d * (prior_prec.ln() - LOG_2PI);
    for (g, t) in grad.iter_mut().zip(theta) {
        *g += -prior_w * prior_prec * t;
    }
    prior_w * lp
}
