//! Bayesian linear regression with known noise — a second conjugate
//! anchor with *correlated* posteriors (the Gaussian-mean anchor is
//! isotropic; this one exercises full-covariance code paths in the
//! combiners).
//!
//! `y_i ~ N(x_i·β, 1/lik_prec)`, `β ~ N(0, I/prior_prec)` powered by
//! `prior_w`. Posterior: `N(Σ* lik_prec Xᵀy, Σ*)` with
//! `Σ*⁻¹ = lik_prec XᵀX + prior_w·prior_prec I`.

use super::{powered_gauss_prior, LogDensity};
use crate::math::linalg::{self, Mat};
use crate::math::mvn::Mvn;
use crate::types::SampleMatrix;

const LOG_2PI: f64 = 1.837_877_066_409_345_5;

/// Gaussian linear model with conjugate Gaussian prior.
#[derive(Debug, Clone)]
pub struct LinearRegression {
    x: SampleMatrix,
    y: Vec<f64>,
    pub lik_prec: f64,
    pub prior_prec: f64,
    pub prior_w: f64,
    /// Cached XᵀX (d × d) and Xᵀy (d).
    xtx: Mat,
    xty: Vec<f64>,
    /// Cached Σ y².
    yty: f64,
}

impl LinearRegression {
    pub fn new(
        x: SampleMatrix,
        y: Vec<f64>,
        lik_prec: f64,
        prior_prec: f64,
        prior_w: f64,
    ) -> Self {
        assert_eq!(x.len(), y.len());
        assert!(lik_prec > 0.0 && prior_prec > 0.0 && prior_w > 0.0);
        let d = x.dim();
        let mut xtx = Mat::zeros(d, d);
        let mut xty = vec![0.0; d];
        let mut yty = 0.0;
        for (row, &yi) in x.rows().zip(&y) {
            for i in 0..d {
                xty[i] += row[i] * yi;
                for j in i..d {
                    xtx[(i, j)] += row[i] * row[j];
                }
            }
            yty += yi * yi;
        }
        for i in 0..d {
            for j in 0..i {
                xtx[(i, j)] = xtx[(j, i)];
            }
        }
        LinearRegression { x, y, lik_prec, prior_prec, prior_w, xtx, xty, yty }
    }

    pub fn n(&self) -> usize {
        self.x.len()
    }

    pub fn data(&self) -> (&SampleMatrix, &[f64]) {
        (&self.x, &self.y)
    }

    /// Closed-form subposterior.
    pub fn exact_posterior(&self) -> Mvn {
        let d = self.x.dim();
        let mut prec = self.xtx.scale(self.lik_prec);
        for i in 0..d {
            prec[(i, i)] += self.prior_w * self.prior_prec;
        }
        let cov = linalg::spd_inverse_jittered(&prec).unwrap();
        let mean = cov
            .matvec(&self.xty.iter().map(|v| v * self.lik_prec).collect::<Vec<_>>())
            .unwrap();
        Mvn::new(mean, cov).unwrap()
    }
}

impl LogDensity for LinearRegression {
    fn dim(&self) -> usize {
        self.x.dim()
    }

    fn logp_grad(&self, theta: &[f64]) -> (f64, Vec<f64>) {
        let d = self.x.dim();
        let n = self.x.len() as f64;
        // -lik_prec/2 (yᵀy - 2 θᵀXᵀy + θᵀXᵀXθ) + n/2 (log lik_prec - log 2π)
        let xtx_t = self.xtx.matvec(theta).unwrap();
        let quad = self.yty - 2.0 * linalg::dot(theta, &self.xty)
            + linalg::dot(theta, &xtx_t);
        let ll = -0.5 * self.lik_prec * quad
            + 0.5 * n * (self.lik_prec.ln() - LOG_2PI);
        let mut grad = vec![0.0; d];
        for j in 0..d {
            grad[j] = self.lik_prec * (self.xty[j] - xtx_t[j]);
        }
        let lp = powered_gauss_prior(theta, self.prior_w, self.prior_prec, &mut grad);
        (ll + lp, grad)
    }

    fn init_point(&self, _rng: &mut crate::rng::Pcg64) -> Vec<f64> {
        self.exact_posterior().mean().to_vec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn toy(seed: u64, n: usize, d: usize) -> LinearRegression {
        let mut rng = Pcg64::seed_from(seed);
        let beta: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let mut x = SampleMatrix::new(d);
        let mut y = Vec::new();
        for _ in 0..n {
            let row: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
            y.push(linalg::dot(&row, &beta) + 0.5 * rng.normal());
            x.push(&row);
        }
        LinearRegression::new(x, y, 4.0, 1.0, 0.5)
    }

    #[test]
    fn grad_matches_finite_diff() {
        let m = toy(1, 50, 3);
        let theta = [0.1, -0.4, 0.8];
        let (_, g) = m.logp_grad(&theta);
        let eps = 1e-6;
        for j in 0..3 {
            let mut tp = theta;
            tp[j] += eps;
            let mut tm = theta;
            tm[j] -= eps;
            let fd = (m.logp(&tp) - m.logp(&tm)) / (2.0 * eps);
            assert!((g[j] - fd).abs() < 1e-3, "dim {j}");
        }
    }

    #[test]
    fn gradient_zero_at_exact_posterior_mean() {
        let m = toy(2, 80, 4);
        let post = m.exact_posterior();
        let (_, g) = m.logp_grad(post.mean());
        assert!(g.iter().all(|v| v.abs() < 1e-7), "{g:?}");
    }

    #[test]
    fn posterior_concentrates_with_data() {
        let small = toy(3, 20, 2);
        let large = toy(3, 2000, 2);
        let vs = small.exact_posterior();
        let vl = large.exact_posterior();
        // Compare marginal variance via logpdf curvature at the mean:
        // bigger n → higher density at the mode.
        assert!(
            vl.logpdf(vl.mean()) > vs.logpdf(vs.mean()),
            "posterior should concentrate"
        );
    }
}
