//! Sample statistics: batch moments, convergence diagnostics, KDE.

pub mod diagnostics;
pub mod kde;
pub mod moments;
