//! Batch mean / covariance / quantiles over [`SampleMatrix`].

use crate::math::linalg::Mat;
use crate::types::SampleMatrix;

/// Sample mean.
pub fn mean(s: &SampleMatrix) -> Vec<f64> {
    let d = s.dim();
    let mut m = vec![0.0; d];
    for row in s.rows() {
        for (mi, &xi) in m.iter_mut().zip(row) {
            *mi += xi;
        }
    }
    let n = s.len().max(1) as f64;
    for mi in m.iter_mut() {
        *mi /= n;
    }
    m
}

/// Unbiased sample covariance (d × d).
pub fn covariance(s: &SampleMatrix) -> Mat {
    let d = s.dim();
    let n = s.len();
    assert!(n >= 2, "need >= 2 draws for covariance");
    let m = mean(s);
    let mut c = Mat::zeros(d, d);
    let mut dev = vec![0.0; d];
    for row in s.rows() {
        for j in 0..d {
            dev[j] = row[j] - m[j];
        }
        for i in 0..d {
            let di = dev[i];
            for j in i..d {
                c[(i, j)] += di * dev[j];
            }
        }
    }
    let denom = (n - 1) as f64;
    for i in 0..d {
        for j in i..d {
            let v = c[(i, j)] / denom;
            c[(i, j)] = v;
            c[(j, i)] = v;
        }
    }
    c
}

/// Per-dimension variance (diagonal of [`covariance`], computed directly).
pub fn variances(s: &SampleMatrix) -> Vec<f64> {
    let d = s.dim();
    let n = s.len();
    assert!(n >= 2);
    let m = mean(s);
    let mut v = vec![0.0; d];
    for row in s.rows() {
        for j in 0..d {
            let dev = row[j] - m[j];
            v[j] += dev * dev;
        }
    }
    for vj in v.iter_mut() {
        *vj /= (n - 1) as f64;
    }
    v
}

/// Weighted mean with non-negative weights.
pub fn weighted_mean(s: &SampleMatrix, w: &[f64]) -> Vec<f64> {
    assert_eq!(s.len(), w.len());
    let d = s.dim();
    let mut m = vec![0.0; d];
    let mut wsum = 0.0;
    for (row, &wi) in s.rows().zip(w) {
        wsum += wi;
        for j in 0..d {
            m[j] += wi * row[j];
        }
    }
    assert!(wsum > 0.0);
    for mj in m.iter_mut() {
        *mj /= wsum;
    }
    m
}

/// `q`-quantile of one coordinate (linear interpolation).
pub fn quantile(s: &SampleMatrix, dim: usize, q: f64) -> f64 {
    assert!((0.0..=1.0).contains(&q));
    let mut xs: Vec<f64> = s.rows().map(|r| r[dim]).collect();
    assert!(!xs.is_empty());
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q * (xs.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        xs[lo]
    } else {
        let frac = pos - lo as f64;
        xs[lo] * (1.0 - frac) + xs[hi] * frac
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture() -> SampleMatrix {
        let mut s = SampleMatrix::new(2);
        s.push(&[1.0, 2.0]);
        s.push(&[3.0, 4.0]);
        s.push(&[5.0, 0.0]);
        s
    }

    #[test]
    fn mean_simple() {
        assert_eq!(mean(&fixture()), vec![3.0, 2.0]);
    }

    #[test]
    fn covariance_matches_hand_calc() {
        let c = covariance(&fixture());
        // devs: (-2,0),(0,2),(2,-2) → var0 = (4+0+4)/2 = 4,
        // var1 = (0+4+4)/2 = 4, cov = (0+0-4)/2 = -2.
        assert!((c[(0, 0)] - 4.0).abs() < 1e-12);
        assert!((c[(1, 1)] - 4.0).abs() < 1e-12);
        assert!((c[(0, 1)] + 2.0).abs() < 1e-12);
        assert_eq!(c[(0, 1)], c[(1, 0)]);
    }

    #[test]
    fn variances_match_cov_diagonal() {
        let s = fixture();
        let c = covariance(&s);
        let v = variances(&s);
        assert!((v[0] - c[(0, 0)]).abs() < 1e-12);
        assert!((v[1] - c[(1, 1)]).abs() < 1e-12);
    }

    #[test]
    fn weighted_mean_downweights() {
        let s = fixture();
        let m = weighted_mean(&s, &[1.0, 0.0, 1.0]);
        assert_eq!(m, vec![3.0, 1.0]);
    }

    #[test]
    fn quantiles() {
        let s = fixture();
        assert_eq!(quantile(&s, 0, 0.0), 1.0);
        assert_eq!(quantile(&s, 0, 0.5), 3.0);
        assert_eq!(quantile(&s, 0, 1.0), 5.0);
        assert_eq!(quantile(&s, 0, 0.25), 2.0);
    }
}
