//! MCMC convergence diagnostics: autocorrelation, effective sample size,
//! split-R̂ (Gelman-Rubin).

use crate::types::SampleMatrix;

/// Autocorrelation of one coordinate at lags 0..max_lag (direct method).
pub fn autocorrelation(s: &SampleMatrix, dim: usize, max_lag: usize) -> Vec<f64> {
    let xs: Vec<f64> = s.rows().map(|r| r[dim]).collect();
    let n = xs.len();
    assert!(n >= 2);
    let mean = xs.iter().sum::<f64>() / n as f64;
    let dev: Vec<f64> = xs.iter().map(|x| x - mean).collect();
    let var: f64 = dev.iter().map(|d| d * d).sum::<f64>() / n as f64;
    let max_lag = max_lag.min(n - 1);
    let mut rho = Vec::with_capacity(max_lag + 1);
    if var == 0.0 {
        rho.resize(max_lag + 1, 1.0);
        return rho;
    }
    for lag in 0..=max_lag {
        let mut acc = 0.0;
        for i in 0..(n - lag) {
            acc += dev[i] * dev[i + lag];
        }
        rho.push(acc / (n as f64 * var));
    }
    rho
}

/// Effective sample size via Geyer's initial positive sequence estimator.
pub fn ess(s: &SampleMatrix, dim: usize) -> f64 {
    let n = s.len();
    if n < 4 {
        return n as f64;
    }
    let rho = autocorrelation(s, dim, (n - 1).min(1000));
    // Sum paired autocorrelations while they stay positive.
    let mut tau = 1.0; // = 1 + 2 Σ ρ_k
    let mut k = 1;
    while k + 1 < rho.len() {
        let pair = rho[k] + rho[k + 1];
        if pair <= 0.0 {
            break;
        }
        tau += 2.0 * pair;
        k += 2;
    }
    (n as f64 / tau).min(n as f64).max(1.0)
}

/// Minimum ESS across all coordinates.
pub fn min_ess(s: &SampleMatrix) -> f64 {
    (0..s.dim())
        .map(|d| ess(s, d))
        .fold(f64::INFINITY, f64::min)
}

/// Split-R̂ over several chains for one coordinate. Values near 1
/// indicate convergence; > 1.05 is suspect.
pub fn split_rhat(chains: &[&SampleMatrix], dim: usize) -> f64 {
    // Split each chain in half → 2C pseudo-chains of equal length.
    let min_len = chains.iter().map(|c| c.len()).min().unwrap_or(0);
    let half = min_len / 2;
    assert!(half >= 2, "chains too short for split-rhat");
    let mut means = Vec::new();
    let mut vars = Vec::new();
    for c in chains {
        for part in 0..2 {
            let lo = part * half;
            let xs: Vec<f64> =
                (lo..lo + half).map(|i| c.row(i)[dim]).collect();
            let m = xs.iter().sum::<f64>() / half as f64;
            let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
                / (half - 1) as f64;
            means.push(m);
            vars.push(v);
        }
    }
    let mchains = means.len() as f64;
    let grand = means.iter().sum::<f64>() / mchains;
    let b = half as f64
        * means.iter().map(|m| (m - grand) * (m - grand)).sum::<f64>()
        / (mchains - 1.0);
    let w = vars.iter().sum::<f64>() / mchains;
    if w == 0.0 {
        return 1.0;
    }
    let var_plus = (half as f64 - 1.0) / half as f64 * w + b / half as f64;
    (var_plus / w).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn iid_chain(seed: u64, n: usize) -> SampleMatrix {
        let mut rng = Pcg64::seed_from(seed);
        let mut s = SampleMatrix::new(1);
        for _ in 0..n {
            s.push(&[rng.normal()]);
        }
        s
    }

    fn ar1_chain(seed: u64, n: usize, phi: f64) -> SampleMatrix {
        let mut rng = Pcg64::seed_from(seed);
        let mut s = SampleMatrix::new(1);
        let mut x = 0.0;
        for _ in 0..n {
            x = phi * x + (1.0 - phi * phi).sqrt() * rng.normal();
            s.push(&[x]);
        }
        s
    }

    #[test]
    fn autocorr_lag0_is_one() {
        let s = iid_chain(1, 500);
        let rho = autocorrelation(&s, 0, 10);
        assert!((rho[0] - 1.0).abs() < 1e-12);
        assert!(rho[1].abs() < 0.1);
    }

    #[test]
    fn ess_iid_near_n() {
        let s = iid_chain(2, 4000);
        let e = ess(&s, 0);
        assert!(e > 2500.0, "ess {e}");
    }

    #[test]
    fn ess_correlated_much_smaller() {
        let s = ar1_chain(3, 4000, 0.95);
        let e = ess(&s, 0);
        // Theoretical τ = (1+φ)/(1-φ) = 39 → ESS ≈ 100.
        assert!(e < 500.0, "ess {e}");
        assert!(e > 20.0, "ess {e}");
    }

    #[test]
    fn rhat_converged_near_one() {
        let a = iid_chain(4, 2000);
        let b = iid_chain(5, 2000);
        let r = split_rhat(&[&a, &b], 0);
        assert!((r - 1.0).abs() < 0.05, "rhat {r}");
    }

    #[test]
    fn rhat_detects_disagreement() {
        let a = iid_chain(6, 2000);
        let mut b = SampleMatrix::new(1);
        let mut rng = Pcg64::seed_from(7);
        for _ in 0..2000 {
            b.push(&[rng.normal() + 5.0]); // shifted chain
        }
        let r = split_rhat(&[&a, &b], 0);
        assert!(r > 1.5, "rhat {r}");
    }
}
