//! Gaussian kernel density estimation.
//!
//! Used by the evaluation layer (the paper's L₂ error metric compares a
//! KDE of the groundtruth chain with a KDE of each method's output) and
//! by the nonparametric combiner's bandwidth rules.

use crate::math::mvn::iso_logpdf;
use crate::math::special::log_sum_exp;
use crate::types::SampleMatrix;

/// Isotropic Gaussian KDE over a set of draws.
#[derive(Debug, Clone)]
pub struct Kde<'a> {
    samples: &'a SampleMatrix,
    bandwidth: f64,
}

impl<'a> Kde<'a> {
    pub fn new(samples: &'a SampleMatrix, bandwidth: f64) -> Self {
        assert!(bandwidth > 0.0 && !samples.is_empty());
        Kde { samples, bandwidth }
    }

    /// Scott's-rule bandwidth: `σ̄ · T^{-1/(d+4)}` with σ̄ the mean
    /// per-dimension standard deviation.
    pub fn with_scott_bandwidth(samples: &'a SampleMatrix) -> Self {
        Kde::new(samples, scott_bandwidth(samples))
    }

    pub fn bandwidth(&self) -> f64 {
        self.bandwidth
    }

    /// Log density at `x`.
    pub fn log_density(&self, x: &[f64]) -> f64 {
        let var = self.bandwidth * self.bandwidth;
        let logs: Vec<f64> = self
            .samples
            .rows()
            .map(|row| iso_logpdf(x, row, var))
            .collect();
        log_sum_exp(&logs) - (self.samples.len() as f64).ln()
    }

    /// Density at `x`.
    pub fn density(&self, x: &[f64]) -> f64 {
        self.log_density(x).exp()
    }
}

/// Scott's rule bandwidth for an isotropic Gaussian kernel.
pub fn scott_bandwidth(samples: &SampleMatrix) -> f64 {
    let t = samples.len() as f64;
    let d = samples.dim() as f64;
    let vars = crate::stats::moments::variances(samples);
    let sd_bar =
        (vars.iter().map(|v| v.sqrt()).sum::<f64>() / d).max(1e-12);
    sd_bar * t.powf(-1.0 / (d + 4.0))
}

/// The paper's annealed IMG bandwidth: `h_i = i^{-1/(4+d)}` (Alg. 1 line 3).
#[inline]
pub fn annealed_bandwidth(iteration: usize, dim: usize) -> f64 {
    (iteration.max(1) as f64).powf(-1.0 / (4.0 + dim as f64))
}

/// Precomputed annealed-bandwidth schedule (ROADMAP rung (c)).
///
/// Every IMG chain of one combine call walks the same `h_i` sequence,
/// so each `powf` needs evaluating once per *combine call*, not once
/// per iteration per chain. The table is filled with
/// [`annealed_bandwidth`] itself and the rare out-of-table lookup
/// falls back to the same function, so schedules read from the table
/// are bit-identical to computing `h_i` inline — pinned by the tests
/// below and, end-to-end, by the combine layer's thread-count /
/// backend byte-identity suites.
#[derive(Debug, Clone)]
pub struct AnnealSchedule {
    dim: usize,
    h: Vec<f64>,
}

impl AnnealSchedule {
    /// Tabulate `h_1 … h_iters` for dimension `dim`.
    pub fn new(dim: usize, iters: usize) -> Self {
        AnnealSchedule {
            dim,
            h: (1..=iters).map(|i| annealed_bandwidth(i, dim)).collect(),
        }
    }

    /// `h_i` (1-based, like Algorithm 1): table lookup, or the direct
    /// computation past the tabulated range.
    #[inline]
    pub fn h(&self, iteration: usize) -> f64 {
        match self.h.get(iteration.wrapping_sub(1)) {
            Some(&h) => h,
            None => annealed_bandwidth(iteration, self.dim),
        }
    }

    /// Number of tabulated iterations.
    pub fn len(&self) -> usize {
        self.h.len()
    }

    pub fn is_empty(&self) -> bool {
        self.h.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn kde_integrates_to_one_1d() {
        let mut rng = Pcg64::seed_from(1);
        let mut s = SampleMatrix::new(1);
        for _ in 0..400 {
            s.push(&[rng.normal()]);
        }
        let kde = Kde::with_scott_bandwidth(&s);
        // Trapezoid over [-6, 6].
        let n = 600;
        let (lo, hi) = (-6.0, 6.0);
        let dx = (hi - lo) / n as f64;
        let mut integral = 0.0;
        for i in 0..=n {
            let x = lo + i as f64 * dx;
            let w = if i == 0 || i == n { 0.5 } else { 1.0 };
            integral += w * kde.density(&[x]) * dx;
        }
        assert!((integral - 1.0).abs() < 0.01, "integral {integral}");
    }

    #[test]
    fn kde_peaks_at_data_mode() {
        let mut s = SampleMatrix::new(1);
        for _ in 0..50 {
            s.push(&[0.0]);
        }
        let kde = Kde::new(&s, 0.5);
        assert!(kde.density(&[0.0]) > kde.density(&[1.0]));
        assert!(kde.density(&[1.0]) > kde.density(&[3.0]));
    }

    /// The schedule table is bit-identical to computing the bandwidth
    /// inline, inside and past the tabulated range — including the
    /// degenerate empty table and the `i = 0` clamp.
    #[test]
    fn anneal_schedule_matches_direct_computation_bitwise() {
        for dim in [1usize, 2, 24] {
            let s = AnnealSchedule::new(dim, 50);
            assert_eq!(s.len(), 50);
            for i in 0..80 {
                assert_eq!(
                    s.h(i).to_bits(),
                    annealed_bandwidth(i, dim).to_bits(),
                    "dim {dim} iteration {i}"
                );
            }
        }
        let empty = AnnealSchedule::new(3, 0);
        assert!(empty.is_empty());
        assert_eq!(
            empty.h(7).to_bits(),
            annealed_bandwidth(7, 3).to_bits()
        );
    }

    #[test]
    fn annealed_bandwidth_decreases() {
        let h1 = annealed_bandwidth(1, 2);
        let h100 = annealed_bandwidth(100, 2);
        let h10000 = annealed_bandwidth(10_000, 2);
        assert_eq!(h1, 1.0);
        assert!(h100 < h1 && h10000 < h100);
        // d = 2 → exponent -1/6.
        assert!((h100 - (100f64).powf(-1.0 / 6.0)).abs() < 1e-12);
    }

    #[test]
    fn scott_bandwidth_shrinks_with_t() {
        let mut rng = Pcg64::seed_from(3);
        let mut small = SampleMatrix::new(2);
        let mut large = SampleMatrix::new(2);
        for i in 0..5000 {
            let row = [rng.normal(), rng.normal()];
            if i < 200 {
                small.push(&row);
            }
            large.push(&row);
        }
        assert!(scott_bandwidth(&large) < scott_bandwidth(&small));
    }
}
