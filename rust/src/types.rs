//! Core data containers shared across the crate.

use crate::error::{Error, Result};

// The chunked, spillable counterpart of [`SampleMatrix`] lives in
// `data/store.rs`; re-exported here because it is the other core draw
// container (the leader's draw plane holds stores, not matrices).
pub use crate::data::store::{DrawStore, DrawStoreConfig, DrawStoreStats};

/// A row-major `T × d` matrix of MCMC samples (one row = one draw of θ).
///
/// This is the interchange type between workers, the leader, the
/// combination algorithms and the evaluation code.
#[derive(Debug, Clone, PartialEq)]
pub struct SampleMatrix {
    data: Vec<f64>,
    dim: usize,
}

impl SampleMatrix {
    /// Empty matrix of draws in `dim` dimensions.
    pub fn new(dim: usize) -> Self {
        assert!(dim > 0, "dim must be positive");
        SampleMatrix { data: Vec::new(), dim }
    }

    /// Empty matrix with capacity for `t` draws.
    pub fn with_capacity(dim: usize, t: usize) -> Self {
        assert!(dim > 0, "dim must be positive");
        SampleMatrix { data: Vec::with_capacity(dim * t), dim }
    }

    /// Build from a flat row-major buffer.
    pub fn from_rows(data: Vec<f64>, dim: usize) -> Result<Self> {
        if dim == 0 || data.len() % dim != 0 {
            return Err(Error::Shape(format!(
                "flat buffer of {} not divisible by dim {}",
                data.len(),
                dim
            )));
        }
        Ok(SampleMatrix { data, dim })
    }

    /// Number of draws.
    pub fn len(&self) -> usize {
        self.data.len() / self.dim
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Dimensionality of θ.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Borrow draw `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.dim..(i + 1) * self.dim]
    }

    /// Append one draw.
    pub fn push(&mut self, theta: &[f64]) {
        assert_eq!(theta.len(), self.dim, "draw has wrong dimension");
        self.data.extend_from_slice(theta);
    }

    /// Append draws from a flat row-major buffer (a whole number of
    /// rows). Bulk counterpart of [`SampleMatrix::push`]: one memcpy
    /// instead of a row-at-a-time loop, used when concatenating
    /// per-chain outputs in the parallel combiner.
    pub fn push_rows(&mut self, flat: &[f64]) {
        assert_eq!(
            flat.len() % self.dim,
            0,
            "flat buffer of {} is not whole rows of dim {}",
            flat.len(),
            self.dim
        );
        self.data.extend_from_slice(flat);
    }

    /// Append all draws of another matrix (must agree on `dim`).
    pub fn extend(&mut self, other: &SampleMatrix) -> Result<()> {
        if other.dim != self.dim {
            return Err(Error::Shape(format!(
                "cannot extend dim {} with dim {}",
                self.dim, other.dim
            )));
        }
        self.data.extend_from_slice(&other.data);
        Ok(())
    }

    /// Flat row-major view.
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// Iterator over draws.
    pub fn rows(&self) -> impl Iterator<Item = &[f64]> {
        self.data.chunks_exact(self.dim)
    }

    /// Iterate over blocks of up to `rows_per_chunk` consecutive draws,
    /// each yielded as one flat row-major slice (the final block may be
    /// shorter). Reductions over a long contiguous slice (sums, squared
    /// norms, scatter updates) vectorize where a per-row `row(i)` loop
    /// re-derives bounds every iteration; the combine-stage caches are
    /// built through this.
    pub fn rows_chunked(
        &self,
        rows_per_chunk: usize,
    ) -> impl Iterator<Item = &[f64]> {
        assert!(rows_per_chunk > 0, "rows_per_chunk must be positive");
        self.data.chunks(self.dim * rows_per_chunk)
    }

    /// Keep draws `[from, len)` — used for burn-in removal.
    pub fn split_off_burnin(&self, from: usize) -> SampleMatrix {
        let from = from.min(self.len());
        SampleMatrix {
            data: self.data[from * self.dim..].to_vec(),
            dim: self.dim,
        }
    }

    /// Every `k`-th draw (thinning).
    pub fn thin(&self, k: usize) -> SampleMatrix {
        assert!(k > 0);
        let mut out = SampleMatrix::with_capacity(self.dim, self.len() / k);
        for i in (0..self.len()).step_by(k) {
            out.push(self.row(i));
        }
        out
    }

    /// First `t` draws (or all if fewer).
    pub fn take(&self, t: usize) -> SampleMatrix {
        let t = t.min(self.len());
        SampleMatrix {
            data: self.data[..t * self.dim].to_vec(),
            dim: self.dim,
        }
    }

    /// Sample mean (length `dim`).
    pub fn mean(&self) -> Vec<f64> {
        crate::stats::moments::mean(self)
    }

    /// Sample covariance (dim × dim, unbiased).
    pub fn covariance(&self) -> crate::math::linalg::Mat {
        crate::stats::moments::covariance(self)
    }

    /// Project onto a subset of coordinates (e.g. the first 2-d marginal).
    pub fn select_dims(&self, dims: &[usize]) -> Result<SampleMatrix> {
        for &d in dims {
            if d >= self.dim {
                return Err(Error::Shape(format!(
                    "dim index {d} out of range (dim={})",
                    self.dim
                )));
            }
        }
        let mut out = SampleMatrix::with_capacity(dims.len(), self.len());
        let mut buf = vec![0.0; dims.len()];
        for row in self.rows() {
            for (j, &d) in dims.iter().enumerate() {
                buf[j] = row[d];
            }
            out.push(&buf);
        }
        Ok(out)
    }
}

/// One machine's output: its subposterior draws plus sampler telemetry.
#[derive(Debug, Clone)]
pub struct SubposteriorSamples {
    /// Worker (machine) index `m ∈ 0..M`.
    pub machine: usize,
    /// Post-burn-in draws from `p_m`.
    pub samples: SampleMatrix,
    /// Mean acceptance rate of the worker's sampler.
    pub accept_rate: f64,
    /// Wall-clock seconds the worker spent sampling (including burn-in).
    pub wall_secs: f64,
    /// Seconds after which draw `i` was available (cumulative, for the
    /// paper's error-vs-time protocol). Length == samples.len().
    pub draw_times: Vec<f64>,
}

impl SubposteriorSamples {
    pub fn new(machine: usize, samples: SampleMatrix) -> Self {
        let n = samples.len();
        SubposteriorSamples {
            machine,
            samples,
            accept_rate: f64::NAN,
            wall_secs: 0.0,
            draw_times: vec![0.0; n],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_row_len() {
        let mut s = SampleMatrix::new(3);
        assert!(s.is_empty());
        s.push(&[1.0, 2.0, 3.0]);
        s.push(&[4.0, 5.0, 6.0]);
        assert_eq!(s.len(), 2);
        assert_eq!(s.row(1), &[4.0, 5.0, 6.0]);
    }

    #[test]
    fn from_rows_validates() {
        assert!(SampleMatrix::from_rows(vec![1.0, 2.0, 3.0], 2).is_err());
        let s = SampleMatrix::from_rows(vec![1.0, 2.0, 3.0, 4.0], 2).unwrap();
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn burnin_and_thin() {
        let mut s = SampleMatrix::new(1);
        for i in 0..10 {
            s.push(&[i as f64]);
        }
        let b = s.split_off_burnin(4);
        assert_eq!(b.len(), 6);
        assert_eq!(b.row(0), &[4.0]);
        let t = s.thin(3);
        assert_eq!(t.len(), 4);
        assert_eq!(t.row(1), &[3.0]);
    }

    #[test]
    fn select_dims_projects() {
        let mut s = SampleMatrix::new(3);
        s.push(&[1.0, 2.0, 3.0]);
        let p = s.select_dims(&[2, 0]).unwrap();
        assert_eq!(p.row(0), &[3.0, 1.0]);
        assert!(s.select_dims(&[5]).is_err());
    }

    #[test]
    fn rows_chunked_covers_all_rows() {
        let mut s = SampleMatrix::new(2);
        for i in 0..5 {
            s.push(&[i as f64, -(i as f64)]);
        }
        let blocks: Vec<&[f64]> = s.rows_chunked(2).collect();
        assert_eq!(blocks.len(), 3); // 2 + 2 + 1 rows
        assert_eq!(blocks[0], &[0.0, -0.0, 1.0, -1.0]);
        assert_eq!(blocks[2], &[4.0, -4.0]);
        let total: usize = blocks.iter().map(|b| b.len()).sum();
        assert_eq!(total, 10);
    }

    #[test]
    fn push_rows_bulk_appends() {
        let mut s = SampleMatrix::new(2);
        s.push_rows(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.len(), 2);
        assert_eq!(s.row(1), &[3.0, 4.0]);
    }

    #[test]
    #[should_panic(expected = "whole rows")]
    fn push_rows_rejects_partial_rows() {
        let mut s = SampleMatrix::new(2);
        s.push_rows(&[1.0, 2.0, 3.0]);
    }

    #[test]
    fn extend_checks_dim() {
        let mut a = SampleMatrix::new(2);
        let mut b = SampleMatrix::new(2);
        b.push(&[1.0, 2.0]);
        a.extend(&b).unwrap();
        assert_eq!(a.len(), 1);
        let c = SampleMatrix::new(3);
        assert!(a.extend(&c).is_err());
    }

    #[test]
    fn take_truncates() {
        let mut s = SampleMatrix::new(1);
        for i in 0..5 {
            s.push(&[i as f64]);
        }
        assert_eq!(s.take(3).len(), 3);
        assert_eq!(s.take(99).len(), 5);
    }
}
