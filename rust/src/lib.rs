//! # repro — Asymptotically Exact, Embarrassingly Parallel MCMC
//!
//! A rust + JAX/Pallas reproduction of Neiswanger, Wang & Xing (2013),
//! *Asymptotically Exact, Embarrassingly Parallel MCMC* (arXiv:1311.4780).
//!
//! The system partitions `N` i.i.d. observations onto `M` independent
//! workers; each worker runs any MCMC sampler on its **subposterior**
//! `p_m(θ) ∝ p(θ)^{1/M} p(x^{n_m}|θ)` with zero communication, and a
//! leader combines the `M` sample streams into draws from (an estimator
//! of) the full-data posterior `p_1 ⋯ p_M(θ) ∝ p(θ|x^N)`.
//!
//! ## Layering
//!
//! * **L3 (this crate)** — coordinator: partitioning ([`coordinator`]),
//!   parallel workers, streaming, the paper's combination algorithms
//!   ([`combine`]), the MCMC substrate ([`sampler`]), evaluation and the
//!   full experiment harness.
//! * **L2/L1 (python, build-time only)** — JAX subposterior graphs with
//!   Pallas likelihood kernels, AOT-lowered to HLO text artifacts that
//!   [`runtime`] loads and executes through the PJRT C API. Python is
//!   never on the sampling path.
//!
//! ## Quickstart
//!
//! ```no_run
//! use repro::prelude::*;
//! use repro::combine::CombineMethod;
//!
//! // Conjugate Gaussian toy problem: 10k points on 4 machines.
//! let data = repro::data::synth::gaussian(10_000, 2, 42);
//! let cfg = PipelineConfig::builder("gaussian")
//!     .machines(4)
//!     .samples_per_machine(2_000)
//!     .method(CombineMethod::Semiparametric)
//!     .build();
//! let out = repro::coordinator::pipeline::run_native(&cfg, &data).unwrap();
//! println!("posterior mean ≈ {:?}", out.combined.mean());
//! ```

pub mod combine;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod evaluation;
pub mod kernel;
pub mod math;
pub mod model;
pub mod rng;
pub mod runtime;
pub mod sampler;
pub mod stats;
pub mod types;

/// Convenience re-exports for downstream users.
pub mod prelude {
    pub use crate::combine::{self, CombineMethod};
    pub use crate::config::PipelineConfig;
    pub use crate::coordinator::pipeline;
    pub use crate::error::{Error, Result};
    pub use crate::model::LogDensity;
    pub use crate::rng::Pcg64;
    pub use crate::sampler::{Chain, Sampler};
    pub use crate::types::SampleMatrix;
}
