//! Distribution samplers layered on [`super::Pcg64`].

use super::Pcg64;
use crate::math::special::lgamma;

impl Pcg64 {
    /// Standard normal via the polar (Marsaglia) method with caching.
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.normal_spare.take() {
            return z;
        }
        loop {
            let u = 2.0 * self.uniform() - 1.0;
            let v = 2.0 * self.uniform() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                self.normal_spare = Some(v * f);
                return u * f;
            }
        }
    }

    /// N(mu, sd²).
    #[inline]
    pub fn normal_with(&mut self, mu: f64, sd: f64) -> f64 {
        mu + sd * self.normal()
    }

    /// Fill a slice with iid standard normals.
    pub fn fill_normal(&mut self, out: &mut [f64]) {
        for v in out.iter_mut() {
            *v = self.normal();
        }
    }

    /// Exponential with rate λ.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        -(1.0 - self.uniform()).ln() / lambda
    }

    /// Gamma(shape α, rate β) via Marsaglia-Tsang squeeze (with the
    /// α < 1 boosting trick).
    pub fn gamma(&mut self, alpha: f64, rate: f64) -> f64 {
        assert!(alpha > 0.0 && rate > 0.0);
        if alpha < 1.0 {
            // Boost: X = gamma(α+1) * U^{1/α}.
            let x = self.gamma(alpha + 1.0, 1.0);
            let u: f64 = self.uniform().max(1e-300);
            return x * u.powf(1.0 / alpha) / rate;
        }
        let d = alpha - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let z = self.normal();
            let v = 1.0 + c * z;
            if v <= 0.0 {
                continue;
            }
            let v3 = v * v * v;
            let u = self.uniform();
            if u < 1.0 - 0.0331 * z.powi(4)
                || u.ln() < 0.5 * z * z + d * (1.0 - v3 + v3.ln())
            {
                return d * v3 / rate;
            }
        }
    }

    /// Beta(a, b) from two gammas.
    pub fn beta(&mut self, a: f64, b: f64) -> f64 {
        let x = self.gamma(a, 1.0);
        let y = self.gamma(b, 1.0);
        x / (x + y)
    }

    /// Poisson(λ): Knuth product for small λ, PTRS transformed rejection
    /// (Hörmann 1993) for large λ.
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        assert!(lambda >= 0.0);
        if lambda == 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.uniform();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        }
        // PTRS.
        let b = 0.931 + 2.53 * lambda.sqrt();
        let a = -0.059 + 0.024_83 * b;
        let inv_alpha = 1.123_9 + 1.132_8 / (b - 3.4);
        let v_r = 0.9277 - 3.6224 / (b - 2.0);
        loop {
            let u = self.uniform() - 0.5;
            let v = self.uniform();
            let us = 0.5 - u.abs();
            let k = ((2.0 * a / us + b) * u + lambda + 0.434_98).floor();
            if us >= 0.07 && v <= v_r {
                return k as u64;
            }
            if k < 0.0 || (us < 0.013 && v > us) {
                continue;
            }
            let lhs = (v * inv_alpha / (a / (us * us) + b)).ln();
            let rhs = -lambda + k * lambda.ln() - lgamma(k + 1.0);
            if lhs <= rhs {
                return k as u64;
            }
        }
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Categorical draw from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0 && total.is_finite(), "bad weights");
        let mut u = self.uniform() * total;
        for (i, &w) in weights.iter().enumerate() {
            u -= w;
            if u < 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Uniform random permutation of 0..n (Fisher-Yates).
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = self.uniform_usize(i + 1);
            p.swap(i, j);
        }
        p
    }

    /// In-place Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.uniform_usize(i + 1);
            items.swap(i, j);
        }
    }

    /// Dirichlet(α) draw.
    pub fn dirichlet(&mut self, alpha: &[f64]) -> Vec<f64> {
        let mut g: Vec<f64> = alpha.iter().map(|&a| self.gamma(a, 1.0)).collect();
        let s: f64 = g.iter().sum();
        for v in g.iter_mut() {
            *v /= s;
        }
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn moments(xs: &[f64]) -> (f64, f64) {
        let n = xs.len() as f64;
        let m = xs.iter().sum::<f64>() / n;
        let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (n - 1.0);
        (m, v)
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seed_from(11);
        let xs: Vec<f64> = (0..50_000).map(|_| rng.normal()).collect();
        let (m, v) = moments(&xs);
        assert!(m.abs() < 0.02, "mean {m}");
        assert!((v - 1.0).abs() < 0.03, "var {v}");
        // Skewness ~ 0.
        let skew = xs.iter().map(|x| x.powi(3)).sum::<f64>() / xs.len() as f64;
        assert!(skew.abs() < 0.05);
    }

    #[test]
    fn gamma_moments() {
        let mut rng = Pcg64::seed_from(13);
        for &(a, r) in &[(0.5, 1.0), (2.0, 3.0), (9.0, 0.5)] {
            let xs: Vec<f64> = (0..40_000).map(|_| rng.gamma(a, r)).collect();
            let (m, v) = moments(&xs);
            assert!((m - a / r).abs() < 0.05 * (a / r).max(1.0), "a={a} mean {m}");
            assert!(
                (v - a / (r * r)).abs() < 0.12 * (a / (r * r)).max(1.0),
                "a={a} var {v}"
            );
        }
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Pcg64::seed_from(17);
        let xs: Vec<f64> = (0..30_000).map(|_| rng.exponential(2.5)).collect();
        let (m, _) = moments(&xs);
        assert!((m - 0.4).abs() < 0.01);
    }

    #[test]
    fn poisson_small_and_large_lambda() {
        let mut rng = Pcg64::seed_from(19);
        for &lam in &[0.5, 4.0, 35.0, 200.0] {
            let xs: Vec<f64> =
                (0..30_000).map(|_| rng.poisson(lam) as f64).collect();
            let (m, v) = moments(&xs);
            assert!((m - lam).abs() < 0.03 * lam.max(3.0), "λ={lam} mean {m}");
            assert!((v - lam).abs() < 0.08 * lam.max(3.0), "λ={lam} var {v}");
        }
    }

    #[test]
    fn categorical_frequencies() {
        let mut rng = Pcg64::seed_from(23);
        let w = [1.0, 2.0, 7.0];
        let mut counts = [0usize; 3];
        for _ in 0..20_000 {
            counts[rng.categorical(&w)] += 1;
        }
        assert!((counts[2] as f64 / 20_000.0 - 0.7).abs() < 0.02);
        assert!((counts[0] as f64 / 20_000.0 - 0.1).abs() < 0.02);
    }

    #[test]
    fn permutation_is_bijection() {
        let mut rng = Pcg64::seed_from(29);
        let p = rng.permutation(100);
        let mut seen = vec![false; 100];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut rng = Pcg64::seed_from(31);
        let d = rng.dirichlet(&[1.0, 2.0, 3.0, 4.0]);
        assert!((d.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(d.iter().all(|&v| v > 0.0));
    }

    #[test]
    fn beta_mean() {
        let mut rng = Pcg64::seed_from(37);
        let xs: Vec<f64> = (0..30_000).map(|_| rng.beta(2.0, 5.0)).collect();
        let (m, _) = moments(&xs);
        assert!((m - 2.0 / 7.0).abs() < 0.01);
    }
}
