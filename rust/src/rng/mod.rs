//! Deterministic, dependency-free random numbers.
//!
//! [`Pcg64`] is the PCG XSL-RR 128/64 generator (O'Neill 2014) — fast,
//! statistically solid, and seedable per worker so every experiment in the
//! repo is exactly reproducible from a root seed. Distribution samplers
//! (normal, gamma, Poisson, …) live on the generator as methods.

mod distributions;

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

/// PCG XSL-RR 128/64.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
    /// Cached second normal from the polar method.
    normal_spare: Option<f64>,
}

impl Pcg64 {
    /// Seed with an explicit state/stream pair.
    pub fn new(seed: u64, stream: u64) -> Self {
        let initseq = ((stream as u128) << 64) | (stream as u128) | 1;
        let mut rng = Pcg64 {
            state: 0,
            inc: (initseq << 1) | 1,
            normal_spare: None,
        };
        rng.step();
        rng.state = rng.state.wrapping_add(
            ((seed as u128) << 64) ^ (seed as u128).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        );
        rng.step();
        rng
    }

    /// Seed with the default stream.
    pub fn seed_from(seed: u64) -> Self {
        Pcg64::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Derive an independent child generator (per-worker streams).
    pub fn split(&mut self, stream: u64) -> Pcg64 {
        Pcg64::new(self.next_u64(), stream.wrapping_mul(2).wrapping_add(1))
    }

    /// Derive `n` independent child generators in one pass.
    ///
    /// The children are a pure function of this generator's state and
    /// `n` is consumed sequentially, so a parallel runtime that hands
    /// child `i` to an arbitrary thread still produces output that is
    /// byte-identical for a fixed root seed regardless of thread count
    /// or scheduling — the contract the parallel combiner relies on.
    pub fn split_n(&mut self, n: usize) -> Vec<Pcg64> {
        (0..n).map(|i| self.split(i as u64)).collect()
    }

    #[inline]
    fn step(&mut self) {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.step();
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        let rot = (self.state >> 122) as u32;
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n) (Lemire's rejection-free-ish method).
    #[inline]
    pub fn uniform_usize(&mut self, n: usize) -> usize {
        assert!(n > 0);
        // 128-bit multiply trick; bias is negligible for n << 2^64 but we
        // still reject in the tail window for exactness.
        let n64 = n as u64;
        let threshold = n64.wrapping_neg() % n64;
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (n64 as u128);
            if (m as u64) >= threshold {
                return (m >> 64) as usize;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg64::seed_from(42);
        let mut b = Pcg64::seed_from(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg64::seed_from(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn split_streams_diverge() {
        let mut root = Pcg64::seed_from(1);
        let mut w0 = root.split(0);
        let mut w1 = root.split(1);
        let same = (0..64).filter(|_| w0.next_u64() == w1.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn split_n_matches_sequential_splits() {
        let mut a = Pcg64::seed_from(3);
        let mut b = Pcg64::seed_from(3);
        let batch = a.split_n(4);
        for (i, mut child) in batch.into_iter().enumerate() {
            let mut seq = b.split(i as u64);
            for _ in 0..16 {
                assert_eq!(child.next_u64(), seq.next_u64());
            }
        }
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut rng = Pcg64::seed_from(5);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn uniform_usize_covers_range() {
        let mut rng = Pcg64::seed_from(9);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            seen[rng.uniform_usize(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
