//! Special functions: log-gamma, digamma, log-sum-exp.

/// Natural log of the gamma function (Lanczos approximation, g=7, n=9).
///
/// Accurate to ~1e-13 over the positive reals; reflected for x < 0.5.
pub fn lgamma(x: f64) -> f64 {
    const COEF: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1_259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_572e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        // Reflection: Γ(x)Γ(1-x) = π / sin(πx).
        return std::f64::consts::PI.ln()
            - (std::f64::consts::PI * x).sin().abs().ln()
            - lgamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = COEF[0];
    let t = x + 7.5;
    for (i, &c) in COEF.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// Digamma ψ(x) via asymptotic series with recurrence shift.
pub fn digamma(mut x: f64) -> f64 {
    let mut result = 0.0;
    while x < 6.0 {
        result -= 1.0 / x;
        x += 1.0;
    }
    let inv = 1.0 / x;
    let inv2 = inv * inv;
    result + x.ln() - 0.5 * inv
        - inv2
            * (1.0 / 12.0
                - inv2 * (1.0 / 120.0 - inv2 * (1.0 / 252.0 - inv2 / 240.0)))
}

/// Numerically stable `log(Σ exp(v_i))`.
pub fn log_sum_exp(v: &[f64]) -> f64 {
    let m = v.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if !m.is_finite() {
        return m;
    }
    m + v.iter().map(|x| (x - m).exp()).sum::<f64>().ln()
}

/// Stable `log(1 + exp(x))` (softplus).
pub fn log1p_exp(x: f64) -> f64 {
    if x > 0.0 {
        x + (-x).exp().ln_1p()
    } else {
        x.exp().ln_1p()
    }
}

/// Logistic sigmoid.
#[inline]
pub fn sigmoid(x: f64) -> f64 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lgamma_matches_factorials() {
        // Γ(n) = (n-1)!
        let facts = [1.0, 1.0, 2.0, 6.0, 24.0, 120.0, 720.0];
        for (n, &f) in facts.iter().enumerate() {
            let x = (n + 1) as f64;
            assert!(
                (lgamma(x) - (f as f64).ln()).abs() < 1e-10,
                "lgamma({x})"
            );
        }
    }

    #[test]
    fn lgamma_half() {
        // Γ(1/2) = √π.
        assert!((lgamma(0.5) - 0.5 * std::f64::consts::PI.ln()).abs() < 1e-10);
    }

    #[test]
    fn digamma_recurrence() {
        // ψ(x+1) = ψ(x) + 1/x.
        for &x in &[0.3, 1.7, 4.2, 11.0] {
            assert!(
                (digamma(x + 1.0) - digamma(x) - 1.0 / x).abs() < 1e-9,
                "x={x}"
            );
        }
    }

    #[test]
    fn digamma_one_is_neg_euler() {
        assert!((digamma(1.0) + 0.577_215_664_901_532_9).abs() < 1e-9);
    }

    #[test]
    fn lse_stable() {
        assert!((log_sum_exp(&[1000.0, 1000.0]) - (1000.0 + 2f64.ln())).abs() < 1e-9);
        assert_eq!(log_sum_exp(&[f64::NEG_INFINITY]), f64::NEG_INFINITY);
        assert!((log_sum_exp(&[0.0, 0.0]) - 2f64.ln()).abs() < 1e-12);
    }

    #[test]
    fn softplus_extremes() {
        assert!((log1p_exp(50.0) - 50.0).abs() < 1e-9);
        assert!(log1p_exp(-50.0) < 1e-20);
        assert!((log1p_exp(0.0) - 2f64.ln()).abs() < 1e-12);
    }

    #[test]
    fn sigmoid_symmetry() {
        for &x in &[-30.0, -2.0, 0.0, 1.3, 25.0] {
            assert!((sigmoid(x) + sigmoid(-x) - 1.0).abs() < 1e-12);
        }
    }
}
