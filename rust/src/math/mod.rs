//! Numeric substrate: dense linear algebra, multivariate normals,
//! special functions and online moment accumulators.
//!
//! The paper's combination stage works with `d × d` covariance matrices
//! (d ≤ ~100 in all experiments), so a straightforward dense
//! implementation is the right tool; everything is allocation-conscious
//! because the IMG hot loop calls into [`mvn`] per proposal.

pub mod linalg;
pub mod mvn;
pub mod running;
pub mod special;

pub use linalg::Mat;
