//! Dense row-major matrices + the factorizations the combiners need.

use crate::error::{Error, Result};

/// Dense row-major `rows × cols` matrix of f64.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    data: Vec<f64>,
    rows: usize,
    cols: usize,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { data: vec![0.0; rows * cols], rows, cols }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Diagonal matrix from a slice.
    pub fn diag(d: &[f64]) -> Self {
        let mut m = Mat::zeros(d.len(), d.len());
        for (i, &v) in d.iter().enumerate() {
            m[(i, i)] = v;
        }
        m
    }

    /// `v * I_n`.
    pub fn scaled_identity(n: usize, v: f64) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = v;
        }
        m
    }

    pub fn from_vec(data: Vec<f64>, rows: usize, cols: usize) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(Error::Shape(format!(
                "buffer {} != {rows}x{cols}",
                data.len()
            )));
        }
        Ok(Mat { data, rows, cols })
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// `self + other`.
    pub fn add(&self, other: &Mat) -> Result<Mat> {
        self.check_same_shape(other)?;
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a + b)
            .collect();
        Ok(Mat { data, rows: self.rows, cols: self.cols })
    }

    /// `self += other`, in place — the allocation-free twin of
    /// [`Mat::add`] for accumulation loops (the per-machine precision
    /// sums), replacing an O(M)-reallocation fold with one buffer.
    /// Element arithmetic is identical to [`Mat::add`].
    pub fn add_assign(&mut self, other: &Mat) -> Result<()> {
        self.check_same_shape(other)?;
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
        Ok(())
    }

    /// `self[(j, j)] += v` for every j — the annealed-schedule diagonal
    /// bump (`+ h²/M I`, `+ M/h² I`) without cloning the matrix.
    pub fn add_diagonal(&mut self, v: f64) {
        assert_eq!(self.rows, self.cols, "add_diagonal of non-square");
        for i in 0..self.rows {
            self[(i, i)] += v;
        }
    }

    /// `self * s` (scalar).
    pub fn scale(&self, s: f64) -> Mat {
        Mat {
            data: self.data.iter().map(|v| v * s).collect(),
            rows: self.rows,
            cols: self.cols,
        }
    }

    /// Matrix-vector product.
    pub fn matvec(&self, v: &[f64]) -> Result<Vec<f64>> {
        let mut out = vec![0.0; self.rows];
        self.matvec_into(v, &mut out)?;
        Ok(out)
    }

    /// Matrix-vector product into a caller-provided buffer — the
    /// allocation-free variant for per-draw hot loops.
    pub fn matvec_into(&self, v: &[f64], out: &mut [f64]) -> Result<()> {
        if v.len() != self.cols || out.len() != self.rows {
            return Err(Error::Shape(format!(
                "matvec: {}x{} * {} -> {}",
                self.rows,
                self.cols,
                v.len(),
                out.len()
            )));
        }
        for i in 0..self.rows {
            out[i] = dot(self.row(i), v);
        }
        Ok(())
    }

    /// Matrix-matrix product.
    pub fn matmul(&self, other: &Mat) -> Result<Mat> {
        if self.cols != other.rows {
            return Err(Error::Shape(format!(
                "matmul: {}x{} * {}x{}",
                self.rows, self.cols, other.rows, other.cols
            )));
        }
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                for j in 0..other.cols {
                    out[(i, j)] += a * other[(k, j)];
                }
            }
        }
        Ok(out)
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    /// Symmetrize in place: `(A + Aᵀ)/2` — guards against fp drift before
    /// Cholesky.
    pub fn symmetrize(&mut self) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                let v = 0.5 * (self[(i, j)] + self[(j, i)]);
                self[(i, j)] = v;
                self[(j, i)] = v;
            }
        }
    }

    fn check_same_shape(&self, other: &Mat) -> Result<()> {
        if self.rows != other.rows || self.cols != other.cols {
            return Err(Error::Shape(format!(
                "{}x{} vs {}x{}",
                self.rows, self.cols, other.rows, other.cols
            )));
        }
        Ok(())
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

/// Dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// `y += a * x`.
#[inline]
pub fn axpy(a: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

/// Squared euclidean distance.
#[inline]
pub fn sq_dist(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// Lower-triangular Cholesky factor `L` with `L Lᵀ = A`.
///
/// `A` must be symmetric positive definite; returns `Error::NotPosDef`
/// otherwise (with the failing pivot in the message).
pub fn cholesky(a: &Mat) -> Result<Mat> {
    if a.rows() != a.cols() {
        return Err(Error::Shape("cholesky of non-square".into()));
    }
    let n = a.rows();
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a[(i, j)];
            for k in 0..j {
                sum -= l[(i, k)] * l[(j, k)];
            }
            if i == j {
                if sum <= 0.0 || !sum.is_finite() {
                    return Err(Error::NotPosDef(format!(
                        "pivot {i} = {sum:.3e}"
                    )));
                }
                l[(i, j)] = sum.sqrt();
            } else {
                l[(i, j)] = sum / l[(j, j)];
            }
        }
    }
    Ok(l)
}

/// Solve `L y = b` (forward substitution) for lower-triangular `L`.
pub fn forward_solve(l: &Mat, b: &[f64]) -> Vec<f64> {
    let n = l.rows();
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l[(i, k)] * y[k];
        }
        y[i] = s / l[(i, i)];
    }
    y
}

/// Solve `L y = b` in place (`b` becomes `y`) — the allocation-free
/// twin of [`forward_solve`] for per-proposal hot loops. Arithmetic is
/// identical (same order of operations), so results match bit-for-bit.
pub fn forward_solve_in_place(l: &Mat, b: &mut [f64]) {
    let n = l.rows();
    debug_assert_eq!(b.len(), n);
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l[(i, k)] * b[k];
        }
        b[i] = s / l[(i, i)];
    }
}

/// Solve `Lᵀ x = y` (back substitution) for lower-triangular `L`.
pub fn backward_solve(l: &Mat, y: &[f64]) -> Vec<f64> {
    let n = l.rows();
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for k in (i + 1)..n {
            s -= l[(k, i)] * x[k];
        }
        x[i] = s / l[(i, i)];
    }
    x
}

/// Solve `A x = b` given the Cholesky factor `L` of `A`.
pub fn chol_solve(l: &Mat, b: &[f64]) -> Vec<f64> {
    backward_solve(l, &forward_solve(l, b))
}

/// Inverse of an SPD matrix via its Cholesky factor.
pub fn chol_inverse(l: &Mat) -> Mat {
    let n = l.rows();
    let mut inv = Mat::zeros(n, n);
    chol_inverse_into(l, &mut inv);
    inv
}

/// [`chol_inverse`] into a caller-owned `n × n` matrix — every element
/// is overwritten, so the buffer need not be zeroed. Bit-identical
/// columns (same solves, same symmetrization).
pub fn chol_inverse_into(l: &Mat, inv: &mut Mat) {
    let n = l.rows();
    debug_assert!(inv.rows() == n && inv.cols() == n);
    let mut e = vec![0.0; n];
    for j in 0..n {
        e[j] = 1.0;
        let col = chol_solve(l, &e);
        for i in 0..n {
            inv[(i, j)] = col[i];
        }
        e[j] = 0.0;
    }
    // Clean up symmetry.
    inv.symmetrize();
}

/// `log det A` from the Cholesky factor of `A`.
pub fn chol_logdet(l: &Mat) -> f64 {
    (0..l.rows()).map(|i| l[(i, i)].ln()).sum::<f64>() * 2.0
}

/// Inverse of an SPD matrix (convenience: factor + invert).
pub fn spd_inverse(a: &Mat) -> Result<Mat> {
    Ok(chol_inverse(&cholesky(a)?))
}

/// Inverse with a diagonal jitter fallback — covariance estimates from
/// small sample counts can be numerically semidefinite; the paper's
/// combiners need Σ̂⁻¹ regardless. Jitter grows ×10 from `1e-10·tr/d`
/// until the factorization succeeds (at most 12 attempts).
pub fn spd_inverse_jittered(a: &Mat) -> Result<Mat> {
    Ok(chol_inverse(&jittered_cholesky(a)?))
}

/// In-place twin of [`spd_inverse_jittered`]: replaces `a` by its
/// (jittered) SPD inverse, writing the result back into `a`'s buffer
/// instead of allocating the output. Both versions factor through
/// [`jittered_cholesky`], so they are bit-identical; callers that
/// still need the input clone first.
pub fn spd_inverse_jittered_in_place(a: &mut Mat) -> Result<()> {
    let l = jittered_cholesky(a)?;
    chol_inverse_into(&l, a);
    Ok(())
}

/// Cholesky with the shared diagonal-jitter escalation policy: try `A`
/// as-is, then retry with `A + jitter·I` for `jitter` growing ×10 from
/// `1e-10·tr/n`, at most 12 attempts (each from a fresh clone of `A`).
///
/// This is the *single copy* of the conditioning fallback behind
/// [`spd_inverse_jittered`], [`spd_inverse_jittered_in_place`] and
/// [`crate::math::mvn::covariance_cholesky`] — the combine layer's
/// byte-identity contracts depend on all of them escalating
/// identically, so keep the policy here.
pub fn jittered_cholesky(a: &Mat) -> Result<Mat> {
    match cholesky(a) {
        Ok(l) => Ok(l),
        Err(_) => {
            let n = a.rows();
            let tr: f64 = (0..n).map(|i| a[(i, i)]).sum();
            let mut jitter = 1e-10 * (tr / n as f64).max(1e-300);
            for _ in 0..12 {
                let mut aj = a.clone();
                for i in 0..n {
                    aj[(i, i)] += jitter;
                }
                if let Ok(l) = cholesky(&aj) {
                    return Ok(l);
                }
                jitter *= 10.0;
            }
            Err(Error::NotPosDef("jittered cholesky failed".into()))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spd3() -> Mat {
        // A = B Bᵀ + I for a fixed B — guaranteed SPD.
        let b = Mat::from_vec(
            vec![1.0, 2.0, 0.5, -1.0, 0.3, 2.0, 0.7, 0.1, 1.5],
            3,
            3,
        )
        .unwrap();
        let mut a = b.matmul(&b.transpose()).unwrap();
        for i in 0..3 {
            a[(i, i)] += 1.0;
        }
        a
    }

    #[test]
    fn cholesky_roundtrip() {
        let a = spd3();
        let l = cholesky(&a).unwrap();
        let back = l.matmul(&l.transpose()).unwrap();
        for i in 0..3 {
            for j in 0..3 {
                assert!((back[(i, j)] - a[(i, j)]).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Mat::from_vec(vec![1.0, 2.0, 2.0, 1.0], 2, 2).unwrap();
        assert!(matches!(cholesky(&a), Err(Error::NotPosDef(_))));
    }

    #[test]
    fn solve_matches_direct() {
        let a = spd3();
        let l = cholesky(&a).unwrap();
        let b = vec![1.0, -2.0, 0.5];
        let x = chol_solve(&l, &b);
        let ax = a.matvec(&x).unwrap();
        for i in 0..3 {
            assert!((ax[i] - b[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn inverse_times_self_is_identity() {
        let a = spd3();
        let inv = spd_inverse(&a).unwrap();
        let prod = a.matmul(&inv).unwrap();
        for i in 0..3 {
            for j in 0..3 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((prod[(i, j)] - want).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn logdet_matches_2x2() {
        let a = Mat::from_vec(vec![2.0, 0.3, 0.3, 1.0], 2, 2).unwrap();
        let l = cholesky(&a).unwrap();
        let det: f64 = 2.0 * 1.0 - 0.3 * 0.3;
        assert!((chol_logdet(&l) - det.ln()).abs() < 1e-12);
    }

    #[test]
    fn jittered_inverse_handles_singular() {
        // Rank-1 covariance (singular).
        let a = Mat::from_vec(vec![1.0, 1.0, 1.0, 1.0], 2, 2).unwrap();
        let inv = spd_inverse_jittered(&a).unwrap();
        assert!(inv.as_slice().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn in_place_jittered_inverse_is_bit_identical() {
        // SPD fast path and the singular jitter path both match the
        // out-of-place version exactly.
        for a in [
            spd3(),
            Mat::from_vec(vec![1.0, 1.0, 1.0, 1.0], 2, 2).unwrap(),
        ] {
            let want = spd_inverse_jittered(&a).unwrap();
            let mut got = a.clone();
            spd_inverse_jittered_in_place(&mut got).unwrap();
            assert_eq!(want.as_slice(), got.as_slice());
        }
    }

    #[test]
    fn add_assign_matches_add() {
        let a = spd3();
        let b = Mat::scaled_identity(3, 0.7);
        let want = a.add(&b).unwrap();
        let mut got = a.clone();
        got.add_assign(&b).unwrap();
        assert_eq!(want.as_slice(), got.as_slice());
        // Shape mismatch is an error, not a panic.
        assert!(got.add_assign(&Mat::identity(2)).is_err());
    }

    #[test]
    fn add_diagonal_matches_manual_bump() {
        let mut a = spd3();
        let mut want = a.clone();
        for i in 0..3 {
            want[(i, i)] += 2.5;
        }
        a.add_diagonal(2.5);
        assert_eq!(a.as_slice(), want.as_slice());
    }

    #[test]
    fn matmul_identity() {
        let a = spd3();
        let i3 = Mat::identity(3);
        assert_eq!(a.matmul(&i3).unwrap(), a);
    }

    #[test]
    fn matvec_shape_error() {
        let a = Mat::identity(3);
        assert!(a.matvec(&[1.0, 2.0]).is_err());
    }

    #[test]
    fn transpose_involution() {
        let m = Mat::from_vec(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], 2, 3).unwrap();
        assert_eq!(m.transpose().transpose(), m);
    }
}
