//! Multivariate normal distribution: log-pdf and sampling.
//!
//! Two flavours: a full-covariance [`Mvn`] (pre-factored once, used by the
//! parametric & semiparametric combiners) and free-function isotropic
//! helpers (used in the IMG mixture-weight hot loop, where each call must
//! be allocation-free).

use crate::error::Result;
use crate::math::linalg::{self, Mat};
use crate::rng::Pcg64;

const LOG_2PI: f64 = 1.837_877_066_409_345_5;

/// Full-covariance multivariate normal `N(μ, Σ)` with Σ pre-factored.
#[derive(Debug, Clone)]
pub struct Mvn {
    mean: Vec<f64>,
    /// Lower Cholesky factor of Σ.
    chol: Mat,
    /// -0.5 (d log 2π + log det Σ).
    log_norm: f64,
}

impl Mvn {
    /// Build from mean and covariance (factored here; jittered if Σ is
    /// numerically semidefinite).
    pub fn new(mean: Vec<f64>, cov: Mat) -> Result<Self> {
        Ok(Self::from_cholesky(mean, covariance_cholesky(cov)?))
    }

    /// Build from a pre-computed lower Cholesky factor of Σ — the
    /// factorization-cache path: the semiparametric combiner factors
    /// each annealed component covariance once and rebuilds the per-draw
    /// `Mvn` in O(d) from the cached factor. `Mvn::new(mean, cov)` is
    /// exactly `from_cholesky(mean, covariance_cholesky(cov))`.
    pub fn from_cholesky(mean: Vec<f64>, chol: Mat) -> Self {
        debug_assert_eq!(chol.rows(), mean.len());
        debug_assert_eq!(chol.cols(), mean.len());
        let d = mean.len() as f64;
        let log_norm = -0.5 * (d * LOG_2PI + linalg::chol_logdet(&chol));
        Mvn { mean, chol, log_norm }
    }

    /// The lower Cholesky factor of Σ.
    pub fn chol(&self) -> &Mat {
        &self.chol
    }

    /// The cached log-normalizer `-0.5 (d log 2π + log det Σ)` — what
    /// [`Mvn::logpdf`] adds to the whitened quadratic form. Exposed so
    /// the combine kernels ([`crate::kernel`]) can evaluate whole
    /// log-density tables against the same factorization with the same
    /// final expression, bit-for-bit.
    pub fn log_norm(&self) -> f64 {
        self.log_norm
    }

    pub fn dim(&self) -> usize {
        self.mean.len()
    }

    pub fn mean(&self) -> &[f64] {
        &self.mean
    }

    /// Log density at `x`.
    pub fn logpdf(&self, x: &[f64]) -> f64 {
        let mut scratch: Vec<f64> = vec![0.0; self.dim()];
        self.logpdf_with(x, &mut scratch)
    }

    /// [`Mvn::logpdf`] with a caller-provided scratch buffer of length
    /// `dim` — allocation-free, for per-proposal hot loops (the
    /// semiparametric IMG numerator). Bit-identical to [`Mvn::logpdf`].
    pub fn logpdf_with(&self, x: &[f64], scratch: &mut [f64]) -> f64 {
        debug_assert_eq!(scratch.len(), self.dim());
        for (s, (a, b)) in scratch.iter_mut().zip(x.iter().zip(&self.mean)) {
            *s = a - b;
        }
        linalg::forward_solve_in_place(&self.chol, scratch);
        self.log_norm - 0.5 * linalg::dot(scratch, scratch)
    }

    /// Draw one sample: μ + L z, z ~ N(0, I).
    pub fn sample(&self, rng: &mut Pcg64) -> Vec<f64> {
        let d = self.dim();
        let mut z = vec![0.0; d];
        let mut out = vec![0.0; d];
        self.sample_into(rng, &mut z, &mut out);
        out
    }

    /// [`Mvn::sample`] with caller-owned scratch (`z`) and output
    /// buffers — allocation-free, for per-draw hot loops. Bit-identical
    /// to [`Mvn::sample`]: same RNG consumption (`dim` normals) and
    /// same accumulation order.
    pub fn sample_into(
        &self,
        rng: &mut Pcg64,
        z: &mut [f64],
        out: &mut [f64],
    ) {
        chol_sample_into(&self.mean, &self.chol, rng, z, out);
    }

    /// Draw `n` samples as a [`crate::types::SampleMatrix`], reusing one
    /// scratch pair across all draws (no per-draw allocation).
    pub fn sample_n(
        &self,
        n: usize,
        rng: &mut Pcg64,
    ) -> crate::types::SampleMatrix {
        let d = self.dim();
        let mut out = crate::types::SampleMatrix::with_capacity(d, n);
        let mut z = vec![0.0; d];
        let mut draw = vec![0.0; d];
        for _ in 0..n {
            self.sample_into(rng, &mut z, &mut draw);
            out.push(&draw);
        }
        out
    }
}

/// Lower Cholesky factor of a covariance matrix with the [`Mvn::new`]
/// conditioning policy: symmetrize first, then the shared
/// diagonal-jitter escalation ([`linalg::jittered_cholesky`]) if Σ is
/// numerically semidefinite. Factored out so the semiparametric
/// annealed-schedule cache can pre-factor component covariances with
/// exactly the arithmetic `Mvn::new` would have applied per draw.
pub fn covariance_cholesky(mut cov: Mat) -> Result<Mat> {
    cov.symmetrize();
    linalg::jittered_cholesky(&cov)
}

/// Draw `mean + L z`, `z ~ N(0, I)`, into a caller-owned buffer with
/// caller-owned normal scratch — the allocation-free primitive behind
/// [`Mvn::sample_into`], used directly by the semiparametric IMG loop
/// where the mean changes per draw but the Cholesky factor is cached
/// per annealed iteration. Consumes exactly `mean.len()` normals in
/// the same order as [`Mvn::sample`] and matches it bit-for-bit.
pub fn chol_sample_into(
    mean: &[f64],
    chol: &Mat,
    rng: &mut Pcg64,
    z: &mut [f64],
    out: &mut [f64],
) {
    let d = mean.len();
    debug_assert_eq!(z.len(), d);
    debug_assert_eq!(out.len(), d);
    debug_assert_eq!(chol.rows(), d);
    for zi in z.iter_mut() {
        *zi = rng.normal();
    }
    out.copy_from_slice(mean);
    for i in 0..d {
        for k in 0..=i {
            out[i] += chol[(i, k)] * z[k];
        }
    }
}

/// Isotropic normal log-pdf: `log N(x | mu, var · I)` — allocation free.
#[inline]
pub fn iso_logpdf(x: &[f64], mu: &[f64], var: f64) -> f64 {
    let d = x.len() as f64;
    let sq = linalg::sq_dist(x, mu);
    -0.5 * (d * (LOG_2PI + var.ln()) + sq / var)
}

/// Isotropic normal log-pdf with `mu = 0`.
#[inline]
pub fn iso_logpdf_zero_mean(x: &[f64], var: f64) -> f64 {
    let d = x.len() as f64;
    let sq: f64 = x.iter().map(|v| v * v).sum();
    -0.5 * (d * (LOG_2PI + var.ln()) + sq / var)
}

/// Scalar normal log-pdf.
#[inline]
pub fn norm_logpdf(x: f64, mu: f64, var: f64) -> f64 {
    let r = x - mu;
    -0.5 * (LOG_2PI + var.ln() + r * r / var)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn logpdf_matches_scalar_formula() {
        let m = Mvn::new(vec![1.0], Mat::diag(&[4.0])).unwrap();
        let want = norm_logpdf(2.0, 1.0, 4.0);
        assert!((m.logpdf(&[2.0]) - want).abs() < 1e-12);
    }

    #[test]
    fn logpdf_standard_2d_at_origin() {
        let m = Mvn::new(vec![0.0, 0.0], Mat::identity(2)).unwrap();
        assert!((m.logpdf(&[0.0, 0.0]) + LOG_2PI).abs() < 1e-12);
    }

    #[test]
    fn iso_matches_full() {
        let mu = vec![0.3, -0.7, 1.1];
        let var = 0.64;
        let m = Mvn::new(mu.clone(), Mat::scaled_identity(3, var)).unwrap();
        let x = [0.1, 0.2, -0.5];
        assert!((m.logpdf(&x) - iso_logpdf(&x, &mu, var)).abs() < 1e-10);
    }

    #[test]
    fn correlated_logpdf_known_value() {
        // 2-d with rho = 0.5, unit variances.
        let cov = Mat::from_vec(vec![1.0, 0.5, 0.5, 1.0], 2, 2).unwrap();
        let m = Mvn::new(vec![0.0, 0.0], cov).unwrap();
        // log N([1,1]) = -log(2π√(1-ρ²)) - (x² - 2ρxy + y²)/(2(1-ρ²))
        let rho: f64 = 0.5;
        let det: f64 = 1.0 - rho * rho;
        let quad = (1.0 - 2.0 * rho + 1.0) / det;
        let want = -LOG_2PI - 0.5 * det.ln() - 0.5 * quad;
        assert!((m.logpdf(&[1.0, 1.0]) - want).abs() < 1e-12);
    }

    #[test]
    fn logpdf_with_scratch_is_bit_identical() {
        let cov = Mat::from_vec(vec![2.0, 0.7, 0.7, 1.5], 2, 2).unwrap();
        let m = Mvn::new(vec![0.4, -0.2], cov).unwrap();
        let mut scratch = vec![0.0; 2];
        for x in [[0.0, 0.0], [1.3, -2.2], [-0.5, 0.9]] {
            assert_eq!(m.logpdf(&x), m.logpdf_with(&x, &mut scratch));
        }
    }

    #[test]
    fn sampling_recovers_moments() {
        let cov = Mat::from_vec(vec![2.0, 0.8, 0.8, 1.0], 2, 2).unwrap();
        let m = Mvn::new(vec![3.0, -1.0], cov).unwrap();
        let mut rng = Pcg64::seed_from(7);
        let s = m.sample_n(20_000, &mut rng);
        let mean = s.mean();
        assert!((mean[0] - 3.0).abs() < 0.05, "mean0 {}", mean[0]);
        assert!((mean[1] + 1.0).abs() < 0.05, "mean1 {}", mean[1]);
        let c = s.covariance();
        assert!((c[(0, 0)] - 2.0).abs() < 0.1);
        assert!((c[(0, 1)] - 0.8).abs() < 0.05);
    }

    #[test]
    fn semidefinite_covariance_is_jittered() {
        let cov = Mat::from_vec(vec![1.0, 1.0, 1.0, 1.0], 2, 2).unwrap();
        let m = Mvn::new(vec![0.0, 0.0], cov).unwrap();
        assert!(m.logpdf(&[0.5, 0.5]).is_finite());
    }

    #[test]
    fn from_cholesky_matches_new() {
        let cov = Mat::from_vec(vec![2.0, 0.7, 0.7, 1.5], 2, 2).unwrap();
        let mean = vec![0.4, -0.2];
        let a = Mvn::new(mean.clone(), cov.clone()).unwrap();
        let chol = covariance_cholesky(cov).unwrap();
        let b = Mvn::from_cholesky(mean, chol);
        assert_eq!(a.chol().as_slice(), b.chol().as_slice());
        assert_eq!(a.logpdf(&[1.0, 2.0]), b.logpdf(&[1.0, 2.0]));
        let mut r1 = Pcg64::seed_from(3);
        let mut r2 = Pcg64::seed_from(3);
        assert_eq!(a.sample(&mut r1), b.sample(&mut r2));
    }

    #[test]
    fn sample_into_is_bit_identical_and_stream_equal() {
        let cov = Mat::from_vec(vec![2.0, 0.8, 0.8, 1.0], 2, 2).unwrap();
        let m = Mvn::new(vec![3.0, -1.0], cov).unwrap();
        let mut r1 = Pcg64::seed_from(11);
        let mut r2 = Pcg64::seed_from(11);
        let mut z = vec![0.0; 2];
        let mut out = vec![0.0; 2];
        for _ in 0..50 {
            let a = m.sample(&mut r1);
            m.sample_into(&mut r2, &mut z, &mut out);
            assert_eq!(a, out);
        }
        // Identical RNG consumption: the streams stay in lockstep.
        assert_eq!(r1.uniform(), r2.uniform());
    }

    #[test]
    fn chol_sample_into_decouples_mean_from_factor() {
        let cov = Mat::from_vec(vec![1.5, 0.4, 0.4, 1.1], 2, 2).unwrap();
        let chol = covariance_cholesky(cov.clone()).unwrap();
        let mean = vec![5.0, -3.0];
        let via_mvn = Mvn::new(mean.clone(), cov).unwrap();
        let mut r1 = Pcg64::seed_from(7);
        let mut r2 = Pcg64::seed_from(7);
        let mut z = vec![0.0; 2];
        let mut out = vec![0.0; 2];
        chol_sample_into(&mean, &chol, &mut r1, &mut z, &mut out);
        assert_eq!(via_mvn.sample(&mut r2), out);
    }
}
