//! Online (Welford) mean/covariance accumulators.
//!
//! The paper (section 4, footnote 3) notes the parametric/semiparametric
//! combiners can update their Gaussian parameters *online* as subposterior
//! samples stream in; this module is that accumulator.

use crate::math::linalg::Mat;

/// Streaming mean + covariance over d-dimensional draws (Welford update).
#[derive(Debug, Clone)]
pub struct RunningMoments {
    dim: usize,
    count: usize,
    mean: Vec<f64>,
    /// Upper-triangular packed sum of outer products of deviations (M2).
    m2: Mat,
}

impl RunningMoments {
    pub fn new(dim: usize) -> Self {
        RunningMoments {
            dim,
            count: 0,
            mean: vec![0.0; dim],
            m2: Mat::zeros(dim, dim),
        }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn count(&self) -> usize {
        self.count
    }

    /// Fold in one draw.
    pub fn push(&mut self, x: &[f64]) {
        assert_eq!(x.len(), self.dim);
        self.count += 1;
        let n = self.count as f64;
        // delta = x - mean; mean += delta / n; m2 += delta ⊗ (x - mean_new)
        let delta: Vec<f64> =
            x.iter().zip(&self.mean).map(|(a, b)| a - b).collect();
        for i in 0..self.dim {
            self.mean[i] += delta[i] / n;
        }
        for i in 0..self.dim {
            let d2i = x[i] - self.mean[i];
            for j in 0..self.dim {
                self.m2[(i, j)] += delta[j] * d2i;
            }
        }
    }

    pub fn mean(&self) -> &[f64] {
        &self.mean
    }

    /// Unbiased covariance (requires ≥ 2 draws).
    pub fn covariance(&self) -> Mat {
        assert!(self.count >= 2, "need at least 2 draws for covariance");
        let mut c = self.m2.scale(1.0 / (self.count as f64 - 1.0));
        c.symmetrize();
        c
    }

    /// Merge another accumulator (Chan et al. parallel update).
    pub fn merge(&mut self, other: &RunningMoments) {
        assert_eq!(self.dim, other.dim);
        if other.count == 0 {
            return;
        }
        if self.count == 0 {
            *self = other.clone();
            return;
        }
        let na = self.count as f64;
        let nb = other.count as f64;
        let n = na + nb;
        let delta: Vec<f64> = other
            .mean
            .iter()
            .zip(&self.mean)
            .map(|(b, a)| b - a)
            .collect();
        for i in 0..self.dim {
            for j in 0..self.dim {
                self.m2[(i, j)] += other.m2[(i, j)]
                    + delta[i] * delta[j] * na * nb / n;
            }
        }
        for i in 0..self.dim {
            self.mean[i] += delta[i] * nb / n;
        }
        self.count += other.count;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::SampleMatrix;

    fn batch(seed: u64, n: usize, d: usize) -> SampleMatrix {
        let mut rng = crate::rng::Pcg64::seed_from(seed);
        let mut s = SampleMatrix::new(d);
        for _ in 0..n {
            let row: Vec<f64> =
                (0..d).map(|j| rng.normal() * (j as f64 + 1.0) + j as f64).collect();
            s.push(&row);
        }
        s
    }

    #[test]
    fn matches_batch_moments() {
        let s = batch(1, 500, 3);
        let mut rm = RunningMoments::new(3);
        for row in s.rows() {
            rm.push(row);
        }
        let bm = s.mean();
        let bc = s.covariance();
        for i in 0..3 {
            assert!((rm.mean()[i] - bm[i]).abs() < 1e-10);
        }
        let rc = rm.covariance();
        for i in 0..3 {
            for j in 0..3 {
                assert!((rc[(i, j)] - bc[(i, j)]).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn merge_equals_concatenation() {
        let a = batch(2, 200, 2);
        let b = batch(3, 350, 2);
        let mut ra = RunningMoments::new(2);
        let mut rb = RunningMoments::new(2);
        for r in a.rows() {
            ra.push(r);
        }
        for r in b.rows() {
            rb.push(r);
        }
        ra.merge(&rb);

        let mut all = a.clone();
        all.extend(&b).unwrap();
        let m = all.mean();
        let c = all.covariance();
        for i in 0..2 {
            assert!((ra.mean()[i] - m[i]).abs() < 1e-10);
            for j in 0..2 {
                assert!((ra.covariance()[(i, j)] - c[(i, j)]).abs() < 1e-9);
            }
        }
        assert_eq!(ra.count(), 550);
    }

    #[test]
    fn merge_with_empty_is_identity() {
        let a = batch(4, 50, 2);
        let mut ra = RunningMoments::new(2);
        for r in a.rows() {
            ra.push(r);
        }
        let before = ra.clone();
        ra.merge(&RunningMoments::new(2));
        assert_eq!(ra.count(), before.count());
        assert_eq!(ra.mean(), before.mean());
    }
}
