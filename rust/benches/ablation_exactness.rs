//! Ablation: convergence of every combiner vs T on the conjugate
//! Gaussian anchor (closed-form posterior ⇒ error is measured against
//! mathematical truth, not a reference chain). Checks Theorem 5.3's
//! qualitative claim: the exact combiners' error shrinks with T while
//! the biased baselines plateau.

#[path = "common/mod.rs"]
mod common;

use repro::combine::{self, CombineMethod};
use repro::config::PipelineConfig;
use repro::coordinator::pipeline;
use repro::data::{io, synth, Dataset};
use repro::evaluation::l2_distance_subsampled;
use repro::model::GaussianMean;
use repro::rng::Pcg64;
use repro::sampler::SamplerKind;
use std::path::Path;

fn main() -> repro::error::Result<()> {
    common::header(
        "ablation_exactness",
        "combiner L2 error vs draws-per-machine T on the conjugate \
         Gaussian (error vs CLOSED-FORM posterior)",
    );
    let machines = 8;
    let data = synth::gaussian(20_000, 2, 101);
    let exact = match &data {
        Dataset::Gaussian { x, lik_prec, prior_prec } => {
            GaussianMean::new(x.clone(), *lik_prec, *prior_prec, 1.0)
                .exact_posterior()
        }
        _ => unreachable!(),
    };
    let mut rng = Pcg64::seed_from(1);
    let exact_draws = exact.sample_n(6_000, &mut rng);

    let ts: Vec<usize> = if common::full_scale() {
        vec![100, 300, 1_000, 3_000, 10_000]
    } else {
        vec![100, 300, 1_000, 3_000]
    };
    let methods = [
        CombineMethod::Parametric,
        CombineMethod::Nonparametric,
        CombineMethod::Semiparametric,
        CombineMethod::SemiparametricNw,
        CombineMethod::Pairwise,
        CombineMethod::SubpostAvg,
        CombineMethod::ConsensusWeighted,
    ];

    let mut table = io::Table::new(&["t", "l2_error"]);
    println!("\n{:>6} {:>18} {:>10}", "T", "method", "L2");
    let mut first_errs = std::collections::BTreeMap::new();
    let mut last_errs = std::collections::BTreeMap::new();
    for &t in &ts {
        let cfg = PipelineConfig::builder("gaussian")
            .machines(machines)
            .samples_per_machine(t)
            .sampler(SamplerKind::Hmc { step: 0.3, n_leapfrog: 8 })
            .seed(55)
            .build();
        let out = pipeline::run_native(&cfg, &data)?;
        for &method in &methods {
            let c = combine::combine(method, &out.subposteriors, t, 5)?;
            // Drop the IMG transient for the MCMC-based combiners.
            let c = if t > 500 { c.split_off_burnin(t / 5) } else { c };
            let err = l2_distance_subsampled(&c, &exact_draws, 300);
            println!("{t:>6} {:>18} {err:>10.4}", method.name());
            table.push(method.name(), vec![t as f64, err]);
            first_errs.entry(method.name()).or_insert(err);
            last_errs.insert(method.name(), err);
        }
    }
    table.write_csv(Path::new("results/ablation_exactness.csv"))?;
    println!("\nwrote results/ablation_exactness.csv");

    println!("\nconvergence summary (first T → last T):");
    for &method in &methods {
        let name = method.name();
        println!(
            "  {name:18} {:.4} → {:.4}",
            first_errs[name], last_errs[name]
        );
    }
    println!(
        "expected shape (Thm 5.3): parametric/nonparametric/semiparametric/\
         pairwise errors shrink with T (Gaussian target, so parametric is \
         also exact here); subpostAvg converges too on this symmetric \
         anchor but is the one that breaks on multimodal targets (fig5)."
    );
    Ok(())
}
