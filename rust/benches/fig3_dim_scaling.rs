//! Figure 3 (right): relative posterior error vs dimension (paper
//! section 8.1.3). For each d, run the M=10 pipeline on synthetic
//! logistic data at a fixed sample budget, score every combiner's L2
//! error against the groundtruth chain, and normalize by the
//! regularChain error at that d (the paper fixes regularChain = 1).

#[path = "common/mod.rs"]
mod common;

use repro::combine::{self, CombineMethod};
use repro::config::PipelineConfig;
use repro::coordinator::pipeline;
use repro::data::{io, synth};
use repro::evaluation::l2_distance_subsampled;
use repro::sampler::SamplerKind;
use std::path::Path;

fn main() -> repro::error::Result<()> {
    common::header(
        "fig3_dim_scaling",
        "relative L2 error vs dimension at a fixed budget, M=10 \
         (regularChain normalized to 1)",
    );
    let dims: Vec<usize> = if common::full_scale() {
        vec![2, 10, 25, 50, 75, 100]
    } else {
        vec![2, 5, 10, 20]
    };
    let (n, t) = if common::full_scale() { (50_000, 1_200) } else { (10_000, 600) };

    let methods = [
        CombineMethod::Parametric,
        CombineMethod::Semiparametric,
        CombineMethod::SemiparametricNw,
        CombineMethod::Nonparametric,
        CombineMethod::SubpostAvg,
    ];
    let mut table = io::Table::new(&["dim", "rel_error"]);
    println!(
        "\n{:>4} {:>14} {:>12} {:>12}",
        "d", "method", "L2", "relative"
    );
    for &d in &dims {
        let data = synth::logistic(n, d, 777);
        let gt_cfg = PipelineConfig::builder("logistic")
            .machines(1)
            .samples_per_machine(t * 2)
            .sampler(SamplerKind::Hmc { step: 0.02, n_leapfrog: 12 })
            .seed(7)
            .build();
        let truth = pipeline::run_single_chain(&gt_cfg, &data)?;

        // regularChain at the budget: a *short* chain (same step budget
        // as one machine sees, but over all N data → fewer draws/sec).
        let rc_cfg = PipelineConfig::builder("logistic")
            .machines(1)
            .samples_per_machine(t / 5)
            .sampler(SamplerKind::Hmc { step: 0.05, n_leapfrog: 10 })
            .seed(8)
            .build();
        let rc = pipeline::run_single_chain(&rc_cfg, &data)?;
        // 2-d marginal scoring (see fig2_error_vs_time.rs) — the
        // normalization by regularChain keeps the paper's "relative
        // error vs d" reading.
        let truth_marg = truth.samples.select_dims(&[0, 1])?;
        let rc_err = l2_distance_subsampled(
            &rc.samples.select_dims(&[0, 1])?,
            &truth_marg,
            250,
        )
        .max(1e-12);

        let cfg = PipelineConfig::builder("logistic")
            .machines(10)
            .samples_per_machine(t)
            .sampler(SamplerKind::Hmc { step: 0.05, n_leapfrog: 10 })
            .seed(99)
            .build();
        let out = pipeline::run_native(&cfg, &data)?;
        for &method in &methods {
            let c = combine::combine(method, &out.subposteriors, t, 5)?;
            let err = l2_distance_subsampled(
                &c.select_dims(&[0, 1])?,
                &truth_marg,
                250,
            );
            let rel = err / rc_err;
            println!("{d:>4} {:>14} {err:>12.5} {rel:>12.3}", method.name());
            table.push(method.name(), vec![d as f64, rel]);
        }
        println!("{d:>4} {:>14} {rc_err:>12.5} {:>12.3}", "regularChain", 1.0);
        table.push("regularChain", vec![d as f64, 1.0]);
    }
    table.write_csv(Path::new("results/fig3_dim_scaling.csv"))?;
    println!("\nwrote results/fig3_dim_scaling.csv");
    println!(
        "expected shape (paper Fig. 3-right): parametric scales best with \
         d, semiparametric a close second; nonparametric degrades fastest \
         but stays usable; subpostAvg is uniformly worse."
    );
    Ok(())
}
