//! Micro-benchmarks of the hot paths, used by the §Perf pass:
//!   L3: IMG sweep cost (cached vs naive), MVN logpdf, gaussian product;
//!   runtime: PJRT logp_grad vs fused 10-step HMC trajectory vs native.
//!
//! Prints ns/op-style rows; writes results/micro_hotpath.csv.

#[path = "common/mod.rs"]
mod common;

use repro::combine::nonparametric::{
    nonparametric, nonparametric_naive, nonparametric_threaded, Img,
};
use repro::combine::semiparametric::{
    semiparametric_threaded, semiparametric_threaded_uncached,
};
use repro::data::{io, synth};
use repro::math::linalg::Mat;
use repro::math::mvn::Mvn;
use repro::model::LogDensity;
use repro::rng::Pcg64;
use repro::types::SampleMatrix;
use std::path::Path;

fn main() -> repro::error::Result<()> {
    common::header("micro_hotpath", "per-component hot-path timings");
    let mut table = io::Table::new(&["ns_per_op"]);
    let mut records: Vec<common::BenchRecord> = Vec::new();
    let mut row = |name: &str, total_secs: f64, ops: usize| {
        let ns = total_secs * 1e9 / ops as f64;
        println!("{name:42} {ns:>12.0} ns/op");
        table.push(name, vec![ns]);
    };

    // --- L3: MVN logpdf (semiparametric inner loop) --------------------
    for d in [2usize, 10, 50] {
        let mvn = Mvn::new(vec![0.0; d], Mat::identity(d)).unwrap();
        let x = vec![0.3; d];
        let n = 100_000;
        let secs = common::time_median(3, || {
            let mut acc = 0.0;
            for _ in 0..n {
                acc += mvn.logpdf(&x);
            }
            std::hint::black_box(acc);
        });
        row(&format!("mvn_logpdf_d{d}"), secs, n);
    }

    // --- L3: IMG sweep, cached vs naive ---------------------------------
    for (m, d) in [(10usize, 10usize), (50, 10), (10, 50)] {
        let mut rng = Pcg64::seed_from(1);
        let sets: Vec<SampleMatrix> = (0..m)
            .map(|_| {
                Mvn::new(vec![0.0; d], Mat::identity(d))
                    .unwrap()
                    .sample_n(500, &mut rng)
            })
            .collect();
        let refs: Vec<&SampleMatrix> = sets.iter().collect();
        let iters = 2_000;
        let secs_fast = common::time_median(3, || {
            let mut img = Img::new(&refs);
            let mut r = Pcg64::seed_from(2);
            std::hint::black_box(img.run(iters, &mut r));
        });
        row(
            &format!("img_sweep_cached_M{m}_d{d}"),
            secs_fast,
            iters * m,
        );
        let secs_naive = common::time_median(3, || {
            std::hint::black_box(
                nonparametric_naive(&refs, iters, 2).unwrap(),
            );
        });
        row(
            &format!("img_sweep_naive_M{m}_d{d}"),
            secs_naive,
            iters * m,
        );
    }

    // --- native logp_grad (logistic, per shard row) ----------------------
    let data = synth::logistic(5_000, 50, 3);
    let idx: Vec<usize> = (0..5_000).collect();
    let native = data.subposterior(&idx, 0.1)?;
    let theta = vec![0.1; 50];
    let n = 200;
    let secs = common::time_median(3, || {
        for _ in 0..n {
            std::hint::black_box(native.logp_grad(&theta));
        }
    });
    row("native_logistic_lpg_n5000_d50", secs, n);

    // --- runtime: PJRT logp_grad + fused trajectory ----------------------
    let dir = Path::new("artifacts");
    if dir.join("manifest.json").exists() {
        use repro::runtime::{RuntimeClient, XlaDensity};
        let client = RuntimeClient::cpu(dir)?;
        let xla = XlaDensity::from_shard(&client, &data, &idx, 0.1)?;
        let secs = common::time_median(3, || {
            for _ in 0..n {
                std::hint::black_box(xla.logp_grad(&theta));
            }
        });
        row("xla_logistic_lpg_n5120_d50", secs, n);

        if xla.has_fused_hmc() {
            let p = vec![0.2; 50];
            let secs_fused = common::time_median(3, || {
                for _ in 0..20 {
                    std::hint::black_box(
                        xla.fused_trajectory(&theta, &p, 0.01, 10),
                    );
                }
            });
            row("xla_fused_hmc10_n5120_d50 (per traj)", secs_fused, 20);
            // Unfused equivalent: 2L+1 ≈ 21 logp_grad calls.
            let secs_unfused = common::time_median(3, || {
                for _ in 0..20 {
                    for _ in 0..21 {
                        std::hint::black_box(xla.logp_grad(&theta));
                    }
                }
            });
            row("xla_unfused_hmc10 (21 lpg calls)", secs_unfused, 20);
            println!(
                "fused-trajectory speedup: {:.1}×",
                secs_unfused / secs_fused
            );
        }
    } else {
        println!("(artifacts/ missing — runtime rows skipped; run `make artifacts`)");
    }

    // --- semiparametric combine: annealed factorization cache ------------
    // Cached vs uncached at d ≥ 20, where the per-iteration O(d³)
    // factorizations dominate the O(d²) IMG sweep work. Byte-identity
    // of the two paths is asserted here, and CI's bench-smoke job fails
    // this binary if the cache ever stops beating the uncached baseline
    // measured in the same run.
    {
        let (m, d, t_sub, t_out) = (8usize, 24usize, 400usize, 2_000usize);
        let mut rng = Pcg64::seed_from(17);
        let sets: Vec<SampleMatrix> = (0..m)
            .map(|_| {
                Mvn::new(vec![0.0; d], Mat::identity(d))
                    .unwrap()
                    .sample_n(t_sub, &mut rng)
            })
            .collect();
        let refs: Vec<&SampleMatrix> = sets.iter().collect();
        let mut cached_out = SampleMatrix::new(d);
        let secs_cached = common::time_median(3, || {
            cached_out = semiparametric_threaded(&refs, t_out, 5, 1).unwrap();
        });
        let mut uncached_out = SampleMatrix::new(d);
        let secs_uncached = common::time_median(3, || {
            uncached_out =
                semiparametric_threaded_uncached(&refs, t_out, 5, 1).unwrap();
        });
        assert_eq!(
            cached_out.as_slice(),
            uncached_out.as_slice(),
            "factorization cache changed the combined draws"
        );
        let speedup = secs_uncached / secs_cached;
        row(
            &format!("semiparametric_combine_uncached_M{m}_d{d}"),
            secs_uncached,
            1,
        );
        row(
            &format!("semiparametric_combine_cached_M{m}_d{d}"),
            secs_cached,
            1,
        );
        let secs_cached4 = common::time_median(3, || {
            std::hint::black_box(
                semiparametric_threaded(&refs, t_out, 5, 4).unwrap(),
            );
        });
        println!(
            "factorization-cache speedup (M={m}, d={d}, t_out={t_out}): \
             {speedup:.1}×  (cached @4 threads: {})",
            common::fmt_secs(secs_cached4)
        );
        records.push(common::BenchRecord {
            name: format!("semiparametric_combine_M{m}_T{t_sub}_d{d}_uncached"),
            ns_per_op: secs_uncached * 1e9,
            threads: 1,
            speedup: 1.0,
        });
        records.push(common::BenchRecord {
            name: format!("semiparametric_combine_M{m}_T{t_sub}_d{d}_cached"),
            ns_per_op: secs_cached * 1e9,
            threads: 1,
            speedup,
        });
        records.push(common::BenchRecord {
            name: format!("semiparametric_combine_M{m}_T{t_sub}_d{d}_cached"),
            ns_per_op: secs_cached4 * 1e9,
            threads: 4,
            speedup: secs_uncached / secs_cached4,
        });
        assert!(
            secs_cached < secs_uncached,
            "cached semiparametric combine ({}) must beat the uncached \
             baseline ({}) — the factorization cache stopped paying for \
             itself",
            common::fmt_secs(secs_cached),
            common::fmt_secs(secs_uncached)
        );
    }

    // --- combine kernels: blocked vs naive log-density table -------------
    // The tentpole gate for the kernel subsystem: the O(TMd²) table at
    // M=8, d=24 (the same shape as the cache rows above) on both CPU
    // backends. Byte-identity is asserted entry-by-entry, and the
    // bench hard-fails if the blocked panels stop beating the scalar
    // reference — CI's bench-smoke job runs this binary, so a kernel
    // perf regression fails the build.
    {
        use repro::combine::GaussianEstimate;
        use repro::kernel::{
            BlockedCpuKernel, CombineKernel, NaiveKernel,
        };
        let (m, d, t_sub) = (8usize, 24usize, 2_000usize);
        let mut rng = Pcg64::seed_from(23);
        let sets: Vec<SampleMatrix> = (0..m)
            .map(|_| {
                Mvn::new(vec![0.0; d], Mat::identity(d))
                    .unwrap()
                    .sample_n(t_sub, &mut rng)
            })
            .collect();
        let mvns: Vec<Mvn> = sets
            .iter()
            .map(|s| GaussianEstimate::fit(s).unwrap().mvn().unwrap())
            .collect();
        let naive = NaiveKernel;
        let blocked = BlockedCpuKernel::default();
        let table_pass = |k: &dyn CombineKernel| -> Vec<Vec<f64>> {
            mvns.iter()
                .zip(&sets)
                .map(|(mvn, s)| k.logpdf_table(mvn, s).unwrap())
                .collect()
        };
        let mut naive_tables = Vec::new();
        let secs_naive = common::time_median(5, || {
            naive_tables = table_pass(&naive);
        });
        let mut blocked_tables = Vec::new();
        let secs_blocked = common::time_median(5, || {
            blocked_tables = table_pass(&blocked);
        });
        for (mach, (a, b)) in
            naive_tables.iter().zip(&blocked_tables).enumerate()
        {
            assert_eq!(a.len(), b.len());
            for (t, (x, y)) in a.iter().zip(b).enumerate() {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "machine {mach} entry {t}: blocked table diverged"
                );
            }
        }
        let ops = m * t_sub;
        row(
            &format!("combine_table_naive_M{m}_d{d}"),
            secs_naive,
            ops,
        );
        row(
            &format!("combine_table_blocked_M{m}_d{d}"),
            secs_blocked,
            ops,
        );
        let speedup = secs_naive / secs_blocked;
        println!(
            "blocked table kernel speedup (M={m}, d={d}, T={t_sub}): \
             {speedup:.2}×"
        );
        records.push(common::BenchRecord {
            name: format!("combine_table_M{m}_T{t_sub}_d{d}_naive"),
            ns_per_op: secs_naive * 1e9,
            threads: 1,
            speedup: 1.0,
        });
        records.push(common::BenchRecord {
            name: format!("combine_table_M{m}_T{t_sub}_d{d}_blocked"),
            ns_per_op: secs_blocked * 1e9,
            threads: 1,
            speedup,
        });
        assert!(
            secs_blocked < secs_naive,
            "blocked table kernel ({}) must beat the naive reference \
             ({}) on the M={m}/d={d} row — the panel kernel stopped \
             paying for itself",
            common::fmt_secs(secs_blocked),
            common::fmt_secs(secs_naive)
        );
    }

    // --- out-of-core draw plane: dense vs chunked table streaming --------
    // The tentpole gate for the chunked DrawStore seam: the same
    // O(TMd²) log-density table, computed in one whole-set pass vs
    // streamed through 64-row chunk views (the shape the store-backed
    // combine feeds the kernel). Byte-identity is asserted entry by
    // entry, and the bench hard-fails if chunking ever costs more than
    // 25% over the dense pass — CI's bench-smoke job runs this binary,
    // so a chunk-seam perf regression fails the build.
    {
        use repro::combine::GaussianEstimate;
        use repro::kernel::{BlockedCpuKernel, CombineKernel};
        let (m, d, t_sub, chunk) = (8usize, 24usize, 2_000usize, 64usize);
        let mut rng = Pcg64::seed_from(29);
        let sets: Vec<SampleMatrix> = (0..m)
            .map(|_| {
                Mvn::new(vec![0.0; d], Mat::identity(d))
                    .unwrap()
                    .sample_n(t_sub, &mut rng)
            })
            .collect();
        let mvns: Vec<Mvn> = sets
            .iter()
            .map(|s| GaussianEstimate::fit(s).unwrap().mvn().unwrap())
            .collect();
        let kernel = BlockedCpuKernel::default();
        let mut dense_tables: Vec<Vec<f64>> = Vec::new();
        let secs_dense = common::time_median(5, || {
            dense_tables = mvns
                .iter()
                .zip(&sets)
                .map(|(mvn, s)| kernel.logpdf_table(mvn, s).unwrap())
                .collect();
        });
        let mut chunked_tables: Vec<Vec<f64>> = Vec::new();
        let secs_chunked = common::time_median(5, || {
            chunked_tables = mvns
                .iter()
                .zip(&sets)
                .map(|(mvn, s)| {
                    let mut col = Vec::with_capacity(s.len());
                    for block in s.rows_chunked(chunk) {
                        kernel
                            .logpdf_table_block(mvn, block, &mut col)
                            .unwrap();
                    }
                    col
                })
                .collect();
        });
        for (mach, (a, b)) in
            dense_tables.iter().zip(&chunked_tables).enumerate()
        {
            assert_eq!(a.len(), b.len());
            for (t, (x, y)) in a.iter().zip(b).enumerate() {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "machine {mach} entry {t}: chunked table diverged"
                );
            }
        }
        let ops = m * t_sub;
        row(&format!("combine_table_dense_M{m}_d{d}"), secs_dense, ops);
        row(
            &format!("combine_table_chunked_M{m}_d{d}"),
            secs_chunked,
            ops,
        );
        println!(
            "chunked table overhead (M={m}, d={d}, T={t_sub}, \
             chunk={chunk}): {:.2}×",
            secs_chunked / secs_dense
        );
        records.push(common::BenchRecord {
            name: format!("combine_table_M{m}_T{t_sub}_d{d}_dense"),
            ns_per_op: secs_dense * 1e9,
            threads: 1,
            speedup: 1.0,
        });
        records.push(common::BenchRecord {
            name: format!("combine_table_M{m}_T{t_sub}_d{d}_chunked"),
            ns_per_op: secs_chunked * 1e9,
            threads: 1,
            speedup: secs_dense / secs_chunked,
        });
        assert!(
            secs_chunked < 1.25 * secs_dense,
            "chunked table streaming ({}) must stay within 1.25× of the \
             dense pass ({}) — the chunk seam got too expensive",
            common::fmt_secs(secs_chunked),
            common::fmt_secs(secs_dense)
        );
    }

    // --- draw plane: JSON vs binary wire at M=8, d=24 --------------------
    // The streaming hot path on both ends: worker-side encode (per-draw
    // JSON frames vs batched binary chunks through a reused scratch
    // buffer) and the full frame round-trip (encode + frame + read +
    // decode). CI's bench-smoke job runs this binary, so the build
    // fails if the binary plane ever stops beating JSON.
    {
        use repro::coordinator::transport::{
            encode_draw, write_frame, write_frame_bytes, DrawEncoder,
            FrameReader, WireFormat, WireMsg,
        };
        use repro::coordinator::worker::DrawMsg;
        use std::io::BufReader;

        let (m_count, d, t_sub) = (8usize, 24usize, 2_000usize);
        let mut rng = Pcg64::seed_from(37);
        let streams: Vec<Vec<DrawMsg>> = (0..m_count)
            .map(|m| {
                (0..t_sub)
                    .map(|i| DrawMsg {
                        machine: m,
                        theta: (0..d).map(|_| rng.normal()).collect(),
                        elapsed: 1e-3 * (i + 1) as f64,
                        last: i + 1 == t_sub,
                    })
                    .collect()
            })
            .collect();
        let ops = m_count * t_sub;

        let encode_pass = |format: WireFormat| -> (f64, usize) {
            let mut bytes_out = 0usize;
            let secs = common::time_median(3, || {
                bytes_out = 0;
                for (m, msgs) in streams.iter().enumerate() {
                    let mut buf: Vec<u8> = Vec::new();
                    {
                        let mut sink = |payload: &[u8]| {
                            write_frame_bytes(&mut buf, payload)
                        };
                        let mut enc =
                            DrawEncoder::new(format, 64, m, d);
                        for msg in msgs {
                            enc.push(msg, &mut sink).unwrap();
                        }
                        enc.flush(&mut sink).unwrap();
                    }
                    bytes_out += buf.len();
                    std::hint::black_box(&buf);
                }
            });
            (secs, bytes_out)
        };
        let (secs_enc_json, bytes_json) = encode_pass(WireFormat::Json);
        let (secs_enc_bin, bytes_bin) = encode_pass(WireFormat::Binary);
        row(&format!("draw_encode_json_M{m_count}_d{d}"), secs_enc_json, ops);
        row(&format!("draw_encode_binary_M{m_count}_d{d}"), secs_enc_bin, ops);
        println!(
            "wire bytes/draw (d={d}): json {:.0}, binary {:.1}  \
             (encode speedup {:.2}×)",
            bytes_json as f64 / ops as f64,
            bytes_bin as f64 / ops as f64,
            secs_enc_json / secs_enc_bin
        );

        let roundtrip_pass = |format: WireFormat| -> f64 {
            common::time_median(3, || {
                let mut scalars = 0usize;
                for (m, msgs) in streams.iter().enumerate() {
                    let mut buf: Vec<u8> = Vec::new();
                    if format == WireFormat::Json {
                        // The seed wire path: one JSON frame per draw.
                        for msg in msgs {
                            write_frame(&mut buf, &encode_draw(msg))
                                .unwrap();
                        }
                    } else {
                        let mut sink = |payload: &[u8]| {
                            write_frame_bytes(&mut buf, payload)
                        };
                        let mut enc =
                            DrawEncoder::new(format, 64, m, d);
                        for msg in msgs {
                            enc.push(msg, &mut sink).unwrap();
                        }
                        enc.flush(&mut sink).unwrap();
                    }
                    let mut r =
                        FrameReader::new(BufReader::new(buf.as_slice()));
                    let mut payload: Vec<u8> = Vec::new();
                    while r.read_frame_into(&mut payload).unwrap().is_some()
                    {
                        match WireMsg::decode_frame(&payload).unwrap() {
                            WireMsg::Draw(dm) => scalars += dm.theta.len(),
                            WireMsg::Chunk(c) => scalars += c.thetas.len(),
                            other => {
                                panic!("unexpected frame {other:?}")
                            }
                        }
                    }
                }
                assert_eq!(scalars, ops * d, "round-trip dropped draws");
                std::hint::black_box(scalars);
            })
        };
        let secs_rt_json = roundtrip_pass(WireFormat::Json);
        let secs_rt_bin = roundtrip_pass(WireFormat::Binary);
        row(
            &format!("frame_roundtrip_json_M{m_count}_d{d}"),
            secs_rt_json,
            ops,
        );
        row(
            &format!("frame_roundtrip_binary_M{m_count}_d{d}"),
            secs_rt_bin,
            ops,
        );
        println!(
            "frame round-trip speedup (M={m_count}, d={d}, T={t_sub}): \
             {:.2}×",
            secs_rt_json / secs_rt_bin
        );
        records.push(common::BenchRecord {
            name: format!("draw_encode_json_M{m_count}_T{t_sub}_d{d}"),
            ns_per_op: secs_enc_json * 1e9,
            threads: 1,
            speedup: 1.0,
        });
        records.push(common::BenchRecord {
            name: format!("draw_encode_binary_M{m_count}_T{t_sub}_d{d}"),
            ns_per_op: secs_enc_bin * 1e9,
            threads: 1,
            speedup: secs_enc_json / secs_enc_bin,
        });
        records.push(common::BenchRecord {
            name: format!("frame_roundtrip_json_M{m_count}_T{t_sub}_d{d}"),
            ns_per_op: secs_rt_json * 1e9,
            threads: 1,
            speedup: 1.0,
        });
        records.push(common::BenchRecord {
            name: format!(
                "frame_roundtrip_binary_M{m_count}_T{t_sub}_d{d}"
            ),
            ns_per_op: secs_rt_bin * 1e9,
            threads: 1,
            speedup: secs_rt_json / secs_rt_bin,
        });
        assert!(
            secs_enc_bin < secs_enc_json,
            "binary draw encode ({}) must beat JSON ({}) at M={m_count}, \
             d={d} — the binary plane stopped paying for itself",
            common::fmt_secs(secs_enc_bin),
            common::fmt_secs(secs_enc_json)
        );
        assert!(
            secs_rt_bin < secs_rt_json,
            "binary frame round-trip ({}) must beat JSON ({}) at \
             M={m_count}, d={d}",
            common::fmt_secs(secs_rt_bin),
            common::fmt_secs(secs_rt_json)
        );
    }

    // --- leader I/O: thread-per-endpoint vs poll(2) reactor at W=64 ------
    // The reactor tentpole's gate: W=64 localhost TCP streams each
    // carrying 256 framed payloads, drained by (a) 64 blocking
    // FrameReader threads — the threads driver's shape, spawn cost
    // included — and (b) one poll(2) loop over nonblocking sockets
    // feeding RecvBuf incremental decoders — the reactor's shape.
    // Checksums must agree, and CI's bench-smoke job hard-fails if the
    // reactor dispatches slower than thread-per-endpoint.
    #[cfg(unix)]
    {
        use repro::coordinator::reactor::{sys, RecvBuf};
        use repro::coordinator::transport::{
            write_frame_bytes, FrameReader, DEFAULT_MAX_FRAME_BYTES,
        };
        use std::io::{Read, Write};
        use std::net::{TcpListener, TcpStream};
        use std::os::unix::io::AsRawFd;

        const W: usize = 64;
        const FRAMES: usize = 256;
        const PAYLOAD: usize = 200;

        // W accepted connection pairs; each server side streams its 256
        // frames from a writer thread and FINs. Setup and writers stay
        // outside the timed region — only the drain is the experiment.
        let setup = || -> (Vec<TcpStream>, Vec<std::thread::JoinHandle<()>>)
        {
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap();
            let mut clients = Vec::with_capacity(W);
            let mut servers = Vec::with_capacity(W);
            for _ in 0..W {
                clients.push(TcpStream::connect(addr).unwrap());
                servers.push(listener.accept().unwrap().0);
            }
            let writers: Vec<_> = servers
                .into_iter()
                .enumerate()
                .map(|(c, mut s)| {
                    std::thread::spawn(move || {
                        let mut buf =
                            Vec::with_capacity(FRAMES * (PAYLOAD + 8));
                        let mut payload = [0u8; PAYLOAD];
                        for i in 0..FRAMES {
                            for (j, b) in payload.iter_mut().enumerate() {
                                *b = ((c + i + j) % 251) as u8;
                            }
                            write_frame_bytes(&mut buf, &payload).unwrap();
                        }
                        s.write_all(&buf).unwrap();
                        // drop → FIN
                    })
                })
                .collect();
            (clients, writers)
        };

        let threads_rep = || -> (f64, u64) {
            let (clients, writers) = setup();
            let t0 = std::time::Instant::now();
            let readers: Vec<_> = clients
                .into_iter()
                .map(|s| {
                    std::thread::spawn(move || {
                        let mut fr = FrameReader::new(
                            std::io::BufReader::new(s),
                        );
                        let mut payload = Vec::new();
                        let mut frames = 0usize;
                        let mut sum = 0u64;
                        while fr
                            .read_frame_into(&mut payload)
                            .unwrap()
                            .is_some()
                        {
                            frames += 1;
                            sum += payload
                                .iter()
                                .map(|&b| b as u64)
                                .sum::<u64>();
                        }
                        (frames, sum)
                    })
                })
                .collect();
            let mut frames = 0usize;
            let mut sum = 0u64;
            for r in readers {
                let (n, s) = r.join().unwrap();
                frames += n;
                sum += s;
            }
            let secs = t0.elapsed().as_secs_f64();
            for w in writers {
                w.join().unwrap();
            }
            assert_eq!(frames, W * FRAMES, "threads drain dropped frames");
            (secs, sum)
        };

        let reactor_rep = || -> (f64, u64) {
            let (streams, writers) = setup();
            for s in &streams {
                s.set_nonblocking(true).unwrap();
            }
            let t0 = std::time::Instant::now();
            let mut bufs: Vec<RecvBuf> = (0..W)
                .map(|_| RecvBuf::new(DEFAULT_MAX_FRAME_BYTES))
                .collect();
            let mut live = vec![true; W];
            let mut payload = Vec::new();
            let mut chunk = [0u8; 65536];
            let mut frames = 0usize;
            let mut sum = 0u64;
            while live.iter().any(|&l| l) {
                let mut fds = Vec::new();
                let mut idx = Vec::new();
                for (c, s) in streams.iter().enumerate() {
                    if live[c] {
                        fds.push(sys::PollFd {
                            fd: s.as_raw_fd(),
                            events: sys::POLLIN,
                            revents: 0,
                        });
                        idx.push(c);
                    }
                }
                sys::poll_fds(&mut fds, 1_000).unwrap();
                for (k, pfd) in fds.iter().enumerate() {
                    if pfd.revents == 0 {
                        continue;
                    }
                    let c = idx[k];
                    let mut eof = false;
                    loop {
                        match (&streams[c]).read(&mut chunk) {
                            Ok(0) => {
                                eof = true;
                                break;
                            }
                            Ok(n) => {
                                bufs[c].extend_from_slice(&chunk[..n])
                            }
                            Err(e)
                                if e.kind()
                                    == std::io::ErrorKind::WouldBlock =>
                            {
                                break
                            }
                            Err(e)
                                if e.kind()
                                    == std::io::ErrorKind::Interrupted =>
                            {
                                continue
                            }
                            Err(e) => panic!("bench reactor read: {e}"),
                        }
                    }
                    while bufs[c]
                        .pop_frame_into(&mut payload, eof)
                        .unwrap()
                        .is_some()
                    {
                        frames += 1;
                        sum += payload
                            .iter()
                            .map(|&b| b as u64)
                            .sum::<u64>();
                    }
                    if eof {
                        live[c] = false;
                    }
                }
            }
            let secs = t0.elapsed().as_secs_f64();
            for w in writers {
                w.join().unwrap();
            }
            assert_eq!(frames, W * FRAMES, "reactor drain dropped frames");
            (secs, sum)
        };

        let median3 = |f: &dyn Fn() -> (f64, u64)| -> (f64, u64) {
            let mut reps: Vec<(f64, u64)> = (0..3).map(|_| f()).collect();
            reps.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            reps[1]
        };
        let (secs_threads, sum_threads) = median3(&threads_rep);
        let (secs_reactor, sum_reactor) = median3(&reactor_rep);
        assert_eq!(
            sum_threads, sum_reactor,
            "dispatch payload checksum diverged across drivers"
        );
        let ops = W * FRAMES;
        row(&format!("frame_dispatch_threads_W{W}"), secs_threads, ops);
        row(&format!("frame_dispatch_reactor_W{W}"), secs_reactor, ops);
        println!(
            "reactor dispatch vs thread-per-endpoint (W={W}, \
             {FRAMES} frames × {PAYLOAD} B): {:.2}×",
            secs_threads / secs_reactor
        );
        records.push(common::BenchRecord {
            name: format!("frame_dispatch_threads_W{W}"),
            ns_per_op: secs_threads * 1e9,
            threads: W,
            speedup: 1.0,
        });
        records.push(common::BenchRecord {
            name: format!("frame_dispatch_reactor_W{W}"),
            ns_per_op: secs_reactor * 1e9,
            threads: 1,
            speedup: secs_threads / secs_reactor,
        });
        assert!(
            secs_reactor <= 1.1 * secs_threads,
            "poll(2) reactor dispatch ({}) must not lose to \
             thread-per-endpoint ({}) at W={W} — the single poller \
             stopped paying for itself",
            common::fmt_secs(secs_reactor),
            common::fmt_secs(secs_threads)
        );
    }

    // --- combine end-to-end at working sizes -----------------------------
    let mut rng = Pcg64::seed_from(9);
    let sets: Vec<SampleMatrix> = (0..10)
        .map(|_| {
            Mvn::new(vec![0.0; 10], Mat::identity(10))
                .unwrap()
                .sample_n(1_000, &mut rng)
        })
        .collect();
    let refs: Vec<&SampleMatrix> = sets.iter().collect();
    let secs = common::time_median(3, || {
        std::hint::black_box(nonparametric(&refs, 1_000, 3).unwrap());
    });
    row("nonparametric_combine_M10_T1000_d10", secs, 1);

    // --- parallel combination runtime: M=10, d=10, T=100k ----------------
    // The §Perf headline: thread-count scaling of the nonparametric
    // combiner at paper scale (T = 100k draws per machine), with a
    // byte-identity check across thread counts. Output draws t_out are
    // scaled down off full mode; T stays at 100k so the shared-cache
    // setup cost is realistic.
    let (t_big, t_out_big) =
        if common::full_scale() { (100_000, 100_000) } else { (100_000, 20_000) };
    let mut rng = Pcg64::seed_from(31);
    let big_sets: Vec<SampleMatrix> = (0..10)
        .map(|_| {
            Mvn::new(vec![0.0; 10], Mat::identity(10))
                .unwrap()
                .sample_n(t_big, &mut rng)
        })
        .collect();
    let big_refs: Vec<&SampleMatrix> = big_sets.iter().collect();
    let mut secs_1t = 0.0;
    let mut baseline: Option<SampleMatrix> = None;
    let mut deterministic = true;
    for &threads in &[1usize, 2, 4, 8] {
        let mut out = SampleMatrix::new(10);
        let secs = common::time_median(3, || {
            out = nonparametric_threaded(&big_refs, t_out_big, 3, threads)
                .unwrap();
        });
        if threads == 1 {
            secs_1t = secs;
            baseline = Some(out.clone());
        } else if let Some(base) = &baseline {
            deterministic &= base.as_slice() == out.as_slice();
        }
        let speedup = if secs > 0.0 { secs_1t / secs } else { 1.0 };
        let name = format!("nonparametric_combine_M10_T{t_big}_d10");
        println!(
            "{name:36} threads={threads} {:>10}   speedup {speedup:>5.2}×",
            common::fmt_secs(secs)
        );
        table.push(&format!("{name}_threads{threads}"), vec![secs * 1e9]);
        records.push(common::BenchRecord {
            name,
            ns_per_op: secs * 1e9,
            threads,
            speedup,
        });
    }
    println!(
        "parallel combine determinism across thread counts: {}",
        if deterministic { "OK (byte-identical)" } else { "FAILED" }
    );
    assert!(deterministic, "thread counts must not change output");

    table.write_csv(Path::new("results/micro_hotpath.csv"))?;
    common::write_bench_json(
        Path::new("results/BENCH_combine.json"),
        &records,
    )?;
    println!("\nwrote results/micro_hotpath.csv");
    println!("wrote results/BENCH_combine.json");
    Ok(())
}
