//! Figure 3 (left): classification accuracy vs time on covtype-like
//! data, M=50 (paper section 8.1.2). Thin bench wrapper over the same
//! protocol as examples/covtype_accuracy.rs, at bench scale.

#[path = "common/mod.rs"]
mod common;

use repro::combine::CombineMethod;
use repro::config::PipelineConfig;
use repro::coordinator::pipeline;
use repro::coordinator::timing::draws_within;
use repro::data::{io, synth, Dataset};
use repro::evaluation::classification_accuracy;
use repro::sampler::SamplerKind;
use repro::types::SampleMatrix;
use std::path::Path;

fn main() -> repro::error::Result<()> {
    common::header(
        "fig3_covtype",
        "classification accuracy vs time, covtype-like, parallel (M=50) \
         vs single chain",
    );
    let (n, d, machines, t) = if common::full_scale() {
        (100_000, 54, 50, 1_000)
    } else {
        (20_000, 20, 20, 400)
    };
    let full = synth::covtype_like(n, d, 2024);
    let (train_idx, test_idx) = synth::train_test_split(n, 0.2, 7);
    let (x_all, y_all, prior_prec) = match &full {
        Dataset::Logistic { x, y, prior_prec } => (x, y, *prior_prec),
        _ => unreachable!(),
    };
    let train = Dataset::Logistic {
        x: repro::data::select_rows(x_all, &train_idx)?,
        y: train_idx.iter().map(|&i| y_all[i]).collect(),
        prior_prec,
    };
    let x_test = repro::data::select_rows(x_all, &test_idx)?;
    let y_test: Vec<f64> = test_idx.iter().map(|&i| y_all[i]).collect();

    let cfg = PipelineConfig::builder("logistic")
        .machines(machines)
        .samples_per_machine(t)
        .sampler(SamplerKind::Hmc { step: 0.05, n_leapfrog: 10 })
        .seed(31)
        .build();
    let out = pipeline::run_native(&cfg, &train)?;
    let single = pipeline::run_single_chain(&cfg, &train)?;

    let horizon = out.timing.sampling_secs.max(single.wall_secs);
    let mut table = io::Table::new(&["budget_secs", "accuracy"]);
    println!("\n{:>10} {:>22} {:>9}", "budget", "method", "accuracy");
    let mut first_par = None;
    let mut first_single = None;
    for i in 1..=8 {
        let b = horizon * i as f64 / 8.0;
        let prefixes: Vec<SampleMatrix> = out
            .subposteriors
            .iter()
            .map(|s| draws_within(s, b))
            .collect();
        if prefixes.iter().all(|p| p.len() >= 10) {
            let refs: Vec<&SampleMatrix> = prefixes.iter().collect();
            let c = repro::combine::combine_sets(
                CombineMethod::Parametric,
                &refs,
                400,
                9,
            )?;
            let acc = classification_accuracy(&c, &x_test, &y_test);
            println!(
                "{:>10} {:>22} {acc:>9.4}",
                common::fmt_secs(b),
                "parallel(parametric)"
            );
            table.push("parallel_parametric", vec![b, acc]);
            if acc > 0.7 && first_par.is_none() {
                first_par = Some(b);
            }
        }
        let prefix = draws_within(&single, b);
        if prefix.len() >= 10 {
            let acc = classification_accuracy(&prefix, &x_test, &y_test);
            println!(
                "{:>10} {:>22} {acc:>9.4}",
                common::fmt_secs(b),
                "regularChain"
            );
            table.push("regularChain", vec![b, acc]);
            if acc > 0.7 && first_single.is_none() {
                first_single = Some(b);
            }
        }
    }
    table.write_csv(Path::new("results/fig3_covtype.csv"))?;
    println!("\nwrote results/fig3_covtype.csv");
    println!(
        "shape check (paper Fig. 3-left): parallel reaches 0.7 accuracy at \
         {} vs single chain {}",
        first_par.map(common::fmt_secs).unwrap_or_else(|| "n/a".into()),
        first_single.map(common::fmt_secs).unwrap_or_else(|| "n/a".into())
    );
    Ok(())
}
