//! Figure 1: Bayesian logistic regression posterior ovals.
//!
//! Regenerates the data behind the paper's 90% probability-mass ovals
//! for the first 2-d marginal: per-subposterior (mean, cov), the
//! parametric density product, the subpostAvg baseline, and the
//! groundtruth chain, at M=10 and M=20. The paper's visual claim becomes
//! two printed checks: (a) the product's mean stays near the truth while
//! subpostAvg's drifts, and (b) the drift grows with M.

#[path = "common/mod.rs"]
mod common;

use repro::combine::CombineMethod;
use repro::config::PipelineConfig;
use repro::coordinator::pipeline;
use repro::data::{io, synth};
use repro::sampler::SamplerKind;
use std::path::Path;

fn mean2_cov2(s: &repro::types::SampleMatrix) -> ([f64; 2], [f64; 3]) {
    let m = s.mean();
    let c = s.covariance();
    ([m[0], m[1]], [c[(0, 0)], c[(0, 1)], c[(1, 1)]])
}

fn dist2(a: &[f64; 2], b: &[f64; 2]) -> f64 {
    ((a[0] - b[0]).powi(2) + (a[1] - b[1]).powi(2)).sqrt()
}

fn main() -> repro::error::Result<()> {
    common::header(
        "fig1_ovals",
        "posterior 90% ovals: product vs averaging, M ∈ {10, 20}",
    );
    let (n, d, t) = if common::full_scale() {
        (50_000, 50, 2_000)
    } else {
        (20_000, 20, 800)
    };
    let data = synth::logistic(n, d, 1234);

    // Groundtruth chain.
    let gt_cfg = PipelineConfig::builder("logistic")
        .machines(1)
        .samples_per_machine(t * 2)
        .sampler(SamplerKind::Hmc { step: 0.02, n_leapfrog: 12 })
        .seed(7)
        .build();
    let truth = pipeline::run_single_chain(&gt_cfg, &data)?;
    let truth2 = truth.samples.select_dims(&[0, 1])?;
    let (truth_mean, truth_cov) = mean2_cov2(&truth2);
    println!(
        "truth marginal: mean=({:.3},{:.3}) cov=({:.4},{:.4},{:.4})",
        truth_mean[0], truth_mean[1], truth_cov[0], truth_cov[1], truth_cov[2]
    );

    let mut table = io::Table::new(&[
        "machines", "mean0", "mean1", "cov00", "cov01", "cov11", "mean_drift",
    ]);
    let mut drift = std::collections::BTreeMap::new();
    for &machines in &[10usize, 20] {
        let cfg = PipelineConfig::builder("logistic")
            .machines(machines)
            .samples_per_machine(t)
            .sampler(SamplerKind::Hmc { step: 0.05, n_leapfrog: 10 })
            .method(CombineMethod::Parametric)
            .seed(99)
            .build();
        let out = pipeline::run_native(&cfg, &data)?;
        for sub in out.subposteriors.iter().take(3) {
            let (m2, c2) = mean2_cov2(&sub.samples.select_dims(&[0, 1])?);
            table.push(
                &format!("sub{}_M{machines}", sub.machine),
                vec![machines as f64, m2[0], m2[1], c2[0], c2[1], c2[2],
                     dist2(&m2, &truth_mean)],
            );
        }
        for &(method, label) in &[
            (CombineMethod::Parametric, "product"),
            (CombineMethod::SubpostAvg, "subpostAvg"),
        ] {
            let c =
                repro::combine::combine(method, &out.subposteriors, t, 5)?;
            let (m2, c2) = mean2_cov2(&c.select_dims(&[0, 1])?);
            let dr = dist2(&m2, &truth_mean);
            println!(
                "M={machines:2} {label:11} mean=({:+.3},{:+.3}) drift={dr:.4}",
                m2[0], m2[1]
            );
            table.push(
                &format!("{label}_M{machines}"),
                vec![machines as f64, m2[0], m2[1], c2[0], c2[1], c2[2], dr],
            );
            drift.insert((label, machines), dr);
        }
    }
    table.write_csv(Path::new("results/fig1_ovals.csv"))?;
    println!("\nwrote results/fig1_ovals.csv");

    // Paper-shape checks.
    let p10 = drift[&("product", 10usize)];
    let p20 = drift[&("product", 20usize)];
    let a10 = drift[&("subpostAvg", 10usize)];
    let a20 = drift[&("subpostAvg", 20usize)];
    println!("\nshape checks (paper Fig. 1):");
    println!(
        "  product tracks truth:        {p10:.4} (M=10), {p20:.4} (M=20)"
    );
    println!(
        "  subpostAvg biased, grows in M: {a10:.4} (M=10) < {a20:.4} (M=20): {}",
        a20 > a10
    );
    println!("  product beats averaging:     {}", p10 < a10 && p20 < a20);
    Ok(())
}
