//! Figure 4: Gaussian-mixture posterior multimodality, quantified.
//!
//! The paper's figure is a scatter plot; its claim is structural:
//! nonparametric/semiparametric draws keep mass on the K! permutation
//! modes of the μ₀ marginal, while parametric and subpostAvg collapse
//! into a single off-mode blob. This bench prints (a) near-mode mass and
//! (b) the number of distinct true-mode regions visited, per method.

#[path = "common/mod.rs"]
mod common;

use repro::combine::{self, CombineMethod};
use repro::config::PipelineConfig;
use repro::coordinator::pipeline;
use repro::data::{io, synth};
use repro::sampler::SamplerKind;
use repro::types::SampleMatrix;
use std::path::Path;

fn main() -> repro::error::Result<()> {
    common::header(
        "fig4_modes",
        "GMM posterior multimodality: near-mode mass + modes visited",
    );
    let (n, k, t) = if common::full_scale() {
        (50_000, 10, 3_000)
    } else {
        (10_000, 4, 1_200)
    };
    let sep = 5.0;
    let data = synth::gmm(n, k, 2, sep, 77);
    let centers = synth::gmm_true_means(k, 2, sep);

    let cfg = PipelineConfig::builder("gmm")
        .machines(10)
        .samples_per_machine(t)
        .sampler(SamplerKind::Rwm { scale: 0.05 })
        .seed(3)
        .build();
    let out = pipeline::run_native(&cfg, &data)?;
    println!(
        "sampled M=10, accept(mean)={:.2}",
        out.metrics.mean_accept_rate()
    );

    let stats = |s: &SampleMatrix| -> (f64, usize) {
        let marg = s.select_dims(&[0, 1]).unwrap();
        let mut near = 0usize;
        let mut visited = vec![0usize; centers.len()];
        for row in marg.rows() {
            for (ci, c) in centers.iter().enumerate() {
                if repro::math::linalg::sq_dist(row, &c[..2]) < 2.25 {
                    near += 1;
                    visited[ci] += 1;
                    break;
                }
            }
        }
        let thresh = (marg.len() as f64 * 0.01) as usize;
        (
            near as f64 / marg.len() as f64,
            visited.iter().filter(|&&v| v > thresh).count(),
        )
    };

    let mut table = io::Table::new(&["near_mode_mass", "modes_visited"]);
    println!(
        "\n{:>18} {:>15} {:>14}",
        "method", "near-mode mass", "modes visited"
    );
    let mut results = std::collections::BTreeMap::new();
    for &method in &[
        CombineMethod::Nonparametric,
        CombineMethod::Semiparametric,
        CombineMethod::SemiparametricNw,
        CombineMethod::Pairwise,
        CombineMethod::Parametric,
        CombineMethod::SubpostAvg,
    ] {
        let c = combine::combine(method, &out.subposteriors, t, 11)?;
        let (mass, modes) = stats(&c);
        println!("{:>18} {mass:>15.3} {modes:>10}/{k}", method.name());
        table.push(method.name(), vec![mass, modes as f64]);
        results.insert(method.name(), (mass, modes));
    }
    table.write_csv(Path::new("results/fig4_modes.csv"))?;
    println!("\nwrote results/fig4_modes.csv");

    let (np_mass, _) = results["nonparametric"];
    let (p_mass, _) = results["parametric"];
    let (avg_mass, _) = results["subpostAvg"];
    println!("\nshape checks (paper Fig. 4):");
    println!(
        "  exact methods keep mass on modes:   nonparametric {np_mass:.2}"
    );
    println!(
        "  biased methods smear it:            parametric {p_mass:.2}, \
         subpostAvg {avg_mass:.2}"
    );
    println!(
        "  ordering holds: {}",
        np_mass > p_mass && np_mass > avg_mass
    );
    Ok(())
}
