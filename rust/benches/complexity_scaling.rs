//! Section 4 complexity claims: direct IMG is O(dTM²) per run with the
//! naive weight evaluation; the paper's pairwise variant is O(dTM); our
//! cached fast path brings direct IMG to O(dTM) as well (the L3 §Perf
//! optimization). This bench measures combine-stage wall-clock vs M and
//! vs d, and fits the growth exponent in M.

#[path = "common/mod.rs"]
mod common;

use repro::combine::nonparametric::{nonparametric, nonparametric_naive};
use repro::combine::pairwise;
use repro::data::io;
use repro::math::linalg::Mat;
use repro::math::mvn::Mvn;
use repro::rng::Pcg64;
use repro::types::SampleMatrix;
use std::path::Path;

fn sets(m: usize, t: usize, d: usize, seed: u64) -> Vec<SampleMatrix> {
    let mut rng = Pcg64::seed_from(seed);
    (0..m)
        .map(|i| {
            let mu = vec![i as f64 * 0.05; d];
            Mvn::new(mu, Mat::scaled_identity(d, 1.0))
                .unwrap()
                .sample_n(t, &mut rng)
        })
        .collect()
}

/// Fit the growth exponent of y ~ x^a by least squares in log-log.
fn growth_exponent(xs: &[f64], ys: &[f64]) -> f64 {
    let n = xs.len() as f64;
    let lx: Vec<f64> = xs.iter().map(|v| v.ln()).collect();
    let ly: Vec<f64> = ys.iter().map(|v| v.ln()).collect();
    let mx = lx.iter().sum::<f64>() / n;
    let my = ly.iter().sum::<f64>() / n;
    let num: f64 =
        lx.iter().zip(&ly).map(|(a, b)| (a - mx) * (b - my)).sum();
    let den: f64 = lx.iter().map(|a| (a - mx) * (a - mx)).sum();
    num / den
}

fn main() -> repro::error::Result<()> {
    common::header(
        "complexity_scaling",
        "combine wall-clock vs M (T, d fixed): naive O(dTM²) vs cached \
         O(dTM) vs pairwise O(dTM)",
    );
    let (t, d, reps) = if common::full_scale() { (2_000, 10, 3) } else { (500, 5, 3) };
    let ms: Vec<usize> = if common::full_scale() {
        vec![2, 4, 8, 16, 32, 64]
    } else {
        vec![2, 4, 8, 16, 32]
    };

    let mut table = io::Table::new(&["machines", "secs"]);
    let mut naive_secs = Vec::new();
    let mut fast_secs = Vec::new();
    let mut pair_secs = Vec::new();
    println!(
        "\n{:>4} {:>12} {:>12} {:>12}",
        "M", "naive", "cached", "pairwise"
    );
    for &m in &ms {
        let s = sets(m, t, d, 42);
        let refs: Vec<&SampleMatrix> = s.iter().collect();
        let tn = common::time_median(reps, || {
            nonparametric_naive(&refs, t, 7).unwrap();
        });
        let tf = common::time_median(reps, || {
            nonparametric(&refs, t, 7).unwrap();
        });
        let tp = common::time_median(reps, || {
            pairwise(&refs, t, 7).unwrap();
        });
        println!(
            "{m:>4} {:>12} {:>12} {:>12}",
            common::fmt_secs(tn),
            common::fmt_secs(tf),
            common::fmt_secs(tp)
        );
        table.push("naive", vec![m as f64, tn]);
        table.push("cached", vec![m as f64, tf]);
        table.push("pairwise", vec![m as f64, tp]);
        naive_secs.push(tn);
        fast_secs.push(tf);
        pair_secs.push(tp);
    }
    let xs: Vec<f64> = ms.iter().map(|&m| m as f64).collect();
    let a_naive = growth_exponent(&xs, &naive_secs);
    let a_fast = growth_exponent(&xs, &fast_secs);
    let a_pair = growth_exponent(&xs, &pair_secs);
    println!("\ngrowth exponents in M (paper: naive 2, others 1):");
    println!("  naive   M^{a_naive:.2}");
    println!("  cached  M^{a_fast:.2}");
    println!("  pairwise M^{a_pair:.2}");

    table.write_csv(Path::new("results/complexity_scaling.csv"))?;
    println!("wrote results/complexity_scaling.csv");

    // Speedup of the cached path at the largest M (§Perf evidence).
    let last = ms.len() - 1;
    println!(
        "cached-path speedup over naive at M={}: {:.1}×",
        ms[last],
        naive_secs[last] / fast_secs[last]
    );
    Ok(())
}
