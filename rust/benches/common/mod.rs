//! Shared benchmark kit (criterion is unavailable offline — DESIGN.md
//! §3 — so benches are `harness = false` binaries built on this module).
//!
//! Conventions: every bench prints paper-style rows to stdout AND writes
//! a CSV under `results/`, so EXPERIMENTS.md can quote either. Set
//! `REPRO_BENCH_FULL=1` for paper-scale workloads (default: scaled-down
//! versions with the same shape).

#![allow(dead_code)] // each bench uses a subset of this kit

use std::io::Write;
use std::path::Path;
use std::time::Instant;

/// True when paper-scale workloads were requested.
pub fn full_scale() -> bool {
    std::env::var("REPRO_BENCH_FULL").map(|v| v == "1").unwrap_or(false)
}

/// Median wall-clock seconds of `reps` runs of `f` (after one warmup).
pub fn time_median<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    f(); // warmup
    let mut times: Vec<f64> = (0..reps.max(1))
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[times.len() / 2]
}

/// Wall-clock of a single run returning its value.
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let v = f();
    (v, t0.elapsed().as_secs_f64())
}

/// Print a bench header.
pub fn header(name: &str, desc: &str) {
    println!("\n=== {name} ===");
    println!("{desc}");
    println!(
        "scale: {}",
        if full_scale() { "FULL (paper)" } else { "scaled (REPRO_BENCH_FULL=1 for paper scale)" }
    );
}

/// One row of a machine-readable benchmark result.
pub struct BenchRecord {
    pub name: String,
    pub ns_per_op: f64,
    pub threads: usize,
    /// Wall-clock speedup vs the 1-thread run of the same benchmark
    /// (1.0 when single-threaded or not comparable).
    pub speedup: f64,
}

/// Escape a string for embedding in a JSON string literal.
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out
}

/// Hand-rolled JSON emitter (serde is unavailable offline): writes
/// `[{"name": …, "ns_per_op": …, "threads": …, "speedup": …}, …]` so
/// the perf trajectory in EXPERIMENTS.md §Perf can be diffed by tools.
pub fn write_bench_json(
    path: &Path,
    records: &[BenchRecord],
) -> std::io::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "[")?;
    for (i, r) in records.iter().enumerate() {
        let comma = if i + 1 < records.len() { "," } else { "" };
        writeln!(
            f,
            "  {{\"name\": \"{}\", \"ns_per_op\": {:.1}, \
             \"threads\": {}, \"speedup\": {:.3}}}{comma}",
            json_escape(&r.name),
            r.ns_per_op,
            r.threads,
            r.speedup
        )?;
    }
    writeln!(f, "]")?;
    Ok(())
}

/// Format seconds with sensible units.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.2}s")
    }
}
