//! Shared benchmark kit (criterion is unavailable offline — DESIGN.md
//! §3 — so benches are `harness = false` binaries built on this module).
//!
//! Conventions: every bench prints paper-style rows to stdout AND writes
//! a CSV under `results/`, so EXPERIMENTS.md can quote either. Set
//! `REPRO_BENCH_FULL=1` for paper-scale workloads (default: scaled-down
//! versions with the same shape).

use std::time::Instant;

/// True when paper-scale workloads were requested.
pub fn full_scale() -> bool {
    std::env::var("REPRO_BENCH_FULL").map(|v| v == "1").unwrap_or(false)
}

/// Median wall-clock seconds of `reps` runs of `f` (after one warmup).
pub fn time_median<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    f(); // warmup
    let mut times: Vec<f64> = (0..reps.max(1))
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[times.len() / 2]
}

/// Wall-clock of a single run returning its value.
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let v = f();
    (v, t0.elapsed().as_secs_f64())
}

/// Print a bench header.
pub fn header(name: &str, desc: &str) {
    println!("\n=== {name} ===");
    println!("{desc}");
    println!(
        "scale: {}",
        if full_scale() { "FULL (paper)" } else { "scaled (REPRO_BENCH_FULL=1 for paper scale)" }
    );
}

/// Format seconds with sensible units.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.2}s")
    }
}
