//! Figure 2: posterior L2 error vs time for logistic regression.
//!
//! Left panel: the three proposed combiners vs regularChain, subpostAvg,
//! subpostPool. Right panel: vs duplicateChainsPool at M ∈ {5, 10, 20}.
//! Time is the paper's cluster model: parallel sampling counts as the
//! max worker clock; the combination cost is added at each budget.

#[path = "common/mod.rs"]
mod common;

use repro::combine::{self, CombineMethod};
use repro::config::PipelineConfig;
use repro::coordinator::pipeline;
use repro::coordinator::timing::draws_within;
use repro::data::{io, synth};
use repro::evaluation::l2_distance_subsampled;
use repro::sampler::SamplerKind;
use repro::types::SampleMatrix;
use std::path::Path;

fn main() -> repro::error::Result<()> {
    common::header(
        "fig2_error_vs_time",
        "posterior L2 error vs time (logistic); left: combiners vs single \
         chain; right: vs duplicate chains",
    );
    let (n, d, t) = if common::full_scale() {
        (50_000, 50, 2_500)
    } else {
        (20_000, 10, 1_200)
    };
    let data = synth::logistic(n, d, 1234);

    // Groundtruth: long full-data chain.
    let gt_cfg = PipelineConfig::builder("logistic")
        .machines(1)
        .samples_per_machine(t * 3)
        .sampler(SamplerKind::Hmc { step: 0.02, n_leapfrog: 12 })
        .seed(7)
        .build();
    let truth = pipeline::run_single_chain(&gt_cfg, &data)?;
    // Score on the first 2-d marginal (as the paper's figures plot):
    // full-dimensional KDE-L2 saturates on concentrated posteriors in
    // d ≳ 10 (diagonal self-terms dominate), losing all discrimination.
    let truth_marg = truth.samples.select_dims(&[0, 1])?;
    let score = |s: &SampleMatrix| -> f64 {
        let m = s.select_dims(&[0, 1]).expect("≥2 dims");
        l2_distance_subsampled(&m, &truth_marg, 300)
    };

    let machines = 10;
    let cfg = PipelineConfig::builder("logistic")
        .machines(machines)
        .samples_per_machine(t)
        .sampler(SamplerKind::Hmc { step: 0.05, n_leapfrog: 10 })
        .seed(99)
        .build();
    let out = pipeline::run_native(&cfg, &data)?;
    // A fresh single chain at the same per-step settings (regularChain).
    let single = pipeline::run_single_chain(&cfg, &data)?;

    let horizon = out.timing.sampling_secs.max(single.wall_secs);
    let budgets: Vec<f64> = (1..=8).map(|i| horizon * i as f64 / 8.0).collect();

    let mut table = io::Table::new(&["budget_secs", "l2_error"]);
    println!("\n-- left panel: combiners vs regularChain --");
    println!("{:>10} {:>14} {:>35}", "budget", "method", "L2 error");
    for &b in &budgets {
        let prefixes: Vec<SampleMatrix> = out
            .subposteriors
            .iter()
            .map(|s| draws_within(s, b))
            .collect();
        let min_len = prefixes.iter().map(|p| p.len()).min().unwrap();
        if min_len >= 20 {
            let refs: Vec<&SampleMatrix> = prefixes.iter().collect();
            for &method in &[
                CombineMethod::Parametric,
                CombineMethod::Nonparametric,
                CombineMethod::Semiparametric,
                CombineMethod::SubpostAvg,
                CombineMethod::SubpostPool,
            ] {
                let (c, csecs) = common::time_once(|| {
                    combine::combine_sets(method, &refs, min_len, 5).unwrap()
                });
                let err = score(&c);
                println!(
                    "{:>10} {:>14} {err:>10.4}  (combine {})",
                    common::fmt_secs(b),
                    method.name(),
                    common::fmt_secs(csecs)
                );
                table.push(&format!("{}", method.name()), vec![b + csecs, err]);
            }
        }
        let prefix = draws_within(&single, b);
        if prefix.len() >= 20 {
            let err = score(&prefix);
            println!(
                "{:>10} {:>14} {err:>10.4}",
                common::fmt_secs(b),
                "regularChain"
            );
            table.push("regularChain", vec![b, err]);
        }
    }

    println!("\n-- right panel: vs duplicateChainsPool, M ∈ {{5,10,20}} --");
    for &m in &[5usize, 10, 20] {
        // Duplicate chains: m independent full-data chains, pooled.
        let mut chains = Vec::new();
        for s in 0..m.min(4) {
            // (cap duplicates in scaled mode; time model extrapolates)
            let mut c = cfg.clone();
            c.seed = 1000 + s as u64;
            chains.push(pipeline::run_single_chain(&c, &data)?);
        }
        let b = horizon;
        let pooled_prefix: Vec<SampleMatrix> =
            chains.iter().map(|c| draws_within(c, b)).collect();
        let refs: Vec<&SampleMatrix> = pooled_prefix.iter().collect();
        if refs.iter().all(|p| !p.is_empty()) {
            let pooled = combine::duplicate_chains_pool(&refs)?;
            let err = score(&pooled);
            println!("M={m:2} duplicateChainsPool @ {:.1}s: L2={err:.4}",
                     b);
            table.push(&format!("duplicateChainsPool_M{m}"), vec![b, err]);
        }

        let mut pc = cfg.clone();
        pc.machines = m;
        let pout = pipeline::run_native(&pc, &data)?;
        let c = combine::combine(
            CombineMethod::Semiparametric,
            &pout.subposteriors,
            t,
            5,
        )?;
        let err = score(&c);
        println!(
            "M={m:2} semiparametric      @ {:.1}s: L2={err:.4} \
             (sampling={:.1}s)",
            pout.timing.total_secs(),
            pout.timing.sampling_secs
        );
        table.push(
            &format!("semiparametric_M{m}"),
            vec![pout.timing.total_secs(), err],
        );
    }

    table.write_csv(Path::new("results/fig2_error_vs_time.csv"))?;
    println!("\nwrote results/fig2_error_vs_time.csv");
    println!(
        "expected shape (paper Fig. 2): combiners reach low error in a \
         fraction of regularChain's time; subpostAvg/subpostPool plateau \
         at high (biased) error; duplicate chains can't parallelize \
         burn-in so they trail the subposterior methods."
    );
    Ok(())
}
