//! Figure 5: posterior L2 error vs time for (left) the multimodal GMM
//! and (right) the Poisson-gamma hierarchical model, M=10.

#[path = "common/mod.rs"]
mod common;

use repro::combine::{self, CombineMethod};
use repro::config::PipelineConfig;
use repro::coordinator::pipeline;
use repro::coordinator::timing::draws_within;
use repro::data::{io, synth, Dataset};
use repro::evaluation::l2_distance_subsampled;
use repro::sampler::SamplerKind;
use repro::types::SampleMatrix;
use std::path::Path;

fn error_vs_time(
    label: &str,
    data: &Dataset,
    cfg: &PipelineConfig,
    gt_cfg: &PipelineConfig,
    select: Option<&[usize]>,
    table: &mut io::Table,
) -> repro::error::Result<()> {
    let truth = pipeline::run_single_chain(gt_cfg, data)?;
    let truth_s = match select {
        Some(dims) => truth.samples.select_dims(dims)?,
        None => truth.samples.clone(),
    };
    let out = pipeline::run_native(cfg, data)?;
    let single = pipeline::run_single_chain(cfg, data)?;
    let horizon = out.timing.sampling_secs.max(single.wall_secs);

    println!("\n-- {label} --");
    println!("{:>10} {:>16} {:>10}", "budget", "method", "L2");
    for i in 1..=6 {
        let b = horizon * i as f64 / 6.0;
        let prefixes: Vec<SampleMatrix> = out
            .subposteriors
            .iter()
            .map(|s| draws_within(s, b))
            .collect();
        let min_len = prefixes.iter().map(|p| p.len()).min().unwrap();
        if min_len >= 20 {
            let refs: Vec<&SampleMatrix> = prefixes.iter().collect();
            for &method in &[
                CombineMethod::Nonparametric,
                CombineMethod::Semiparametric,
                CombineMethod::Parametric,
                CombineMethod::SubpostAvg,
            ] {
                let c = combine::combine_sets(method, &refs, min_len, 5)?;
                let cs = match select {
                    Some(dims) => c.select_dims(dims)?,
                    None => c,
                };
                let err = l2_distance_subsampled(&cs, &truth_s, 250);
                println!(
                    "{:>10} {:>16} {err:>10.4}",
                    common::fmt_secs(b),
                    method.name()
                );
                table.push(&format!("{label}:{}", method.name()), vec![b, err]);
            }
        }
        let prefix = draws_within(&single, b);
        if prefix.len() >= 20 {
            let ps = match select {
                Some(dims) => prefix.select_dims(dims)?,
                None => prefix,
            };
            let err = l2_distance_subsampled(&ps, &truth_s, 250);
            println!(
                "{:>10} {:>16} {err:>10.4}",
                common::fmt_secs(b),
                "regularChain"
            );
            table.push(&format!("{label}:regularChain"), vec![b, err]);
        }
    }
    Ok(())
}

fn main() -> repro::error::Result<()> {
    common::header(
        "fig5_gmm_pg",
        "L2 error vs time: multimodal GMM (left) + Poisson-gamma (right)",
    );
    let full = common::full_scale();
    let mut table = io::Table::new(&["budget_secs", "l2_error"]);

    // Left: GMM over component means (score on the 2-d μ₀ marginal, as
    // the paper plots).
    let (n_g, k, t_g) = if full { (50_000, 10, 1_500) } else { (8_000, 4, 600) };
    let gmm = synth::gmm(n_g, k, 2, 5.0, 77);
    let gmm_cfg = PipelineConfig::builder("gmm")
        .machines(10)
        .samples_per_machine(t_g)
        .sampler(SamplerKind::Rwm { scale: 0.08 })
        .seed(3)
        .build();
    let gmm_gt = PipelineConfig::builder("gmm")
        .machines(1)
        .samples_per_machine(t_g * 3)
        .sampler(SamplerKind::Rwm { scale: 0.08 })
        .seed(4)
        .build();
    error_vs_time("gmm", &gmm, &gmm_cfg, &gmm_gt, Some(&[0, 1]), &mut table)?;

    // Right: Poisson-gamma (θ = (log a, log b)).
    let n_p = if full { 50_000 } else { 10_000 };
    let t_p = if full { 1_500 } else { 600 };
    let pg = synth::poisson_gamma(n_p, 9);
    let pg_cfg = PipelineConfig::builder("poisson_gamma")
        .machines(10)
        .samples_per_machine(t_p)
        .sampler(SamplerKind::Hmc { step: 0.02, n_leapfrog: 10 })
        .seed(5)
        .build();
    let pg_gt = PipelineConfig::builder("poisson_gamma")
        .machines(1)
        .samples_per_machine(t_p * 3)
        .sampler(SamplerKind::Hmc { step: 0.02, n_leapfrog: 10 })
        .seed(6)
        .build();
    error_vs_time("poisson_gamma", &pg, &pg_cfg, &pg_gt, None, &mut table)?;

    table.write_csv(Path::new("results/fig5_gmm_pg.csv"))?;
    println!("\nwrote results/fig5_gmm_pg.csv");
    println!(
        "expected shape (paper Fig. 5): nonparametric/semiparametric reach \
         low error quickly on the multimodal GMM where parametric and \
         subpostAvg stay high (bias); on Poisson-gamma all combiners \
         converge fast relative to the full chain."
    );
    Ok(())
}
