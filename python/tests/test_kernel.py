"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

This is the CORE correctness signal for the compile path: the hypothesis
sweeps randomize shapes, block sizes, masks, and value ranges; fixed cases
pin down numerically extreme regimes.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gmm, logistic, ref

F32 = np.float32


def _allclose(a, b, atol=1e-4, rtol=1e-4):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=atol, rtol=rtol)


# ---------------------------------------------------------------------------
# Logistic kernel
# ---------------------------------------------------------------------------


def _logistic_case(seed, n, d, frac_masked, scale):
    rng = np.random.default_rng(seed)
    x = (scale * rng.normal(size=(n, d))).astype(F32)
    y = (rng.random(n) < 0.5).astype(F32)
    mask = np.ones(n, F32)
    n_masked = int(frac_masked * n)
    if n_masked:
        mask[n - n_masked:] = 0.0
    beta = rng.normal(size=d).astype(F32)
    return x, y, mask, beta


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    log2_blocks=st.integers(0, 3),
    block_n=st.sampled_from([8, 16, 32, 64]),
    d=st.integers(1, 40),
    frac_masked=st.floats(0.0, 0.9),
    scale=st.floats(0.05, 3.0),
)
def test_logistic_kernel_matches_ref(seed, log2_blocks, block_n, d,
                                     frac_masked, scale):
    n = block_n * (2 ** log2_blocks)
    x, y, mask, beta = _logistic_case(seed, n, d, frac_masked, scale)
    ll, g = logistic.loglik_grad(
        jnp.array(x), jnp.array(y), jnp.array(mask), jnp.array(beta),
        block_n=block_n,
    )
    ll_r, g_r = ref.logistic_loglik_grad(x, y, mask, beta)
    # Tolerance scales with shard size (f32 accumulation order differs).
    tol = 1e-4 * max(1.0, n / 64)
    _allclose(ll, ll_r, atol=tol, rtol=1e-4)
    _allclose(g, g_r, atol=tol, rtol=1e-4)


def test_logistic_kernel_fully_masked_is_zero():
    x, y, _, beta = _logistic_case(0, 64, 7, 0.0, 1.0)
    mask = np.zeros(64, F32)
    ll, g = logistic.loglik_grad(
        jnp.array(x), jnp.array(y), jnp.array(mask), jnp.array(beta),
        block_n=16,
    )
    assert float(ll) == 0.0
    assert np.all(np.asarray(g) == 0.0)


def test_logistic_kernel_extreme_logits_finite():
    """softplus must stay stable for |z| ~ 60 (naive log(1+e^z) overflows)."""
    rng = np.random.default_rng(3)
    n, d = 32, 4
    x = (30.0 * rng.normal(size=(n, d))).astype(F32)
    y = (rng.random(n) < 0.5).astype(F32)
    mask = np.ones(n, F32)
    beta = np.full(d, 2.0, F32)
    ll, g = logistic.loglik_grad(
        jnp.array(x), jnp.array(y), jnp.array(mask), jnp.array(beta),
        block_n=16,
    )
    ll_r, g_r = ref.logistic_loglik_grad(x, y, mask, beta)
    assert np.isfinite(float(ll)) and np.all(np.isfinite(np.asarray(g)))
    _allclose(ll, ll_r, atol=1e-2, rtol=1e-4)
    _allclose(g, g_r, atol=1e-3, rtol=1e-4)


def test_logistic_kernel_rejects_unaligned_n():
    x, y, mask, beta = _logistic_case(0, 48, 3, 0.0, 1.0)
    with pytest.raises(ValueError):
        logistic.loglik_grad(
            jnp.array(x), jnp.array(y), jnp.array(mask), jnp.array(beta),
            block_n=32,
        )


def test_pad_rows_and_choose_block():
    assert logistic.pad_rows(5000, 512) == 5120
    assert logistic.pad_rows(5120, 512) == 5120
    assert logistic.pad_rows(1, 512) == 512
    assert logistic.choose_block_n(10_000) == logistic.DEFAULT_BLOCK_N
    b = logistic.choose_block_n(100)
    assert b >= 100 and b % 2 == 0


# ---------------------------------------------------------------------------
# GMM kernel
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    log2_blocks=st.integers(0, 2),
    block_n=st.sampled_from([8, 16, 32]),
    k=st.integers(1, 12),
    dim=st.integers(1, 5),
    inv_var=st.floats(0.1, 10.0),
    frac_masked=st.floats(0.0, 0.9),
)
def test_gmm_kernel_matches_ref(seed, log2_blocks, block_n, k, dim,
                                inv_var, frac_masked):
    n = block_n * (2 ** log2_blocks)
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, dim)).astype(F32) * 3.0
    mask = np.ones(n, F32)
    n_masked = int(frac_masked * n)
    if n_masked:
        mask[n - n_masked:] = 0.0
    mu = rng.normal(size=(k, dim)).astype(F32) * 3.0
    w = rng.dirichlet(np.ones(k)).astype(F32)
    logw = np.log(np.maximum(w, 1e-6)).astype(F32)
    iv = np.array([inv_var], F32)

    ll, g = gmm.loglik_grad(
        jnp.array(x), jnp.array(mask), jnp.array(mu), jnp.array(logw),
        jnp.array(iv), block_n=block_n,
    )
    ll_r, g_r = ref.gmm_loglik_grad(x, mask, mu, logw, inv_var)
    tol = 2e-4 * max(1.0, n / 32) * max(1.0, inv_var)
    _allclose(ll, ll_r, atol=tol, rtol=2e-4)
    _allclose(g, g_r, atol=tol, rtol=2e-3)


def test_gmm_kernel_single_component_is_gaussian():
    """K=1 GMM log-lik == sum of Gaussian log-pdfs."""
    rng = np.random.default_rng(7)
    n, dim = 32, 2
    x = rng.normal(size=(n, dim)).astype(F32)
    mask = np.ones(n, F32)
    mu = np.zeros((1, dim), F32)
    logw = np.zeros(1, F32)
    iv = np.array([1.0], F32)
    ll, _ = gmm.loglik_grad(
        jnp.array(x), jnp.array(mask), jnp.array(mu), jnp.array(logw),
        jnp.array(iv), block_n=16,
    )
    expected = float(
        -0.5 * np.sum(x * x) - n * dim * 0.5 * np.log(2 * np.pi)
    )
    assert abs(float(ll) - expected) < 1e-2


def test_gmm_kernel_fully_masked_is_zero():
    rng = np.random.default_rng(9)
    n, dim, k = 16, 2, 3
    x = rng.normal(size=(n, dim)).astype(F32)
    mask = np.zeros(n, F32)
    mu = rng.normal(size=(k, dim)).astype(F32)
    logw = np.log(np.ones(k, F32) / k)
    ll, g = gmm.loglik_grad(
        jnp.array(x), jnp.array(mask), jnp.array(mu), jnp.array(logw),
        jnp.array([1.0], F32), block_n=16,
    )
    assert float(ll) == 0.0
    assert np.allclose(np.asarray(g), 0.0)
