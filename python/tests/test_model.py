"""L2 model correctness: gradients vs autodiff, leapfrog physics, priors."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model

F32 = np.float32


def _finite_diff_grad(f, theta, eps=1e-3):
    g = np.zeros_like(theta)
    for i in range(theta.shape[0]):
        tp = theta.copy(); tp[i] += eps
        tm = theta.copy(); tm[i] -= eps
        g[i] = (float(f(jnp.array(tp))) - float(f(jnp.array(tm)))) / (2 * eps)
    return g


# ---------------------------------------------------------------------------
# Gradient consistency
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), prior_w=st.floats(0.05, 1.0))
def test_logistic_grad_matches_autodiff(seed, prior_w):
    rng = np.random.default_rng(seed)
    n, d = 32, 6
    x = rng.normal(size=(n, d)).astype(F32)
    y = (rng.random(n) < 0.5).astype(F32)
    mask = np.ones(n, F32)
    beta = rng.normal(size=d).astype(F32)

    def lp(b):
        v, _ = model.logistic_logp_grad(
            jnp.array(x), jnp.array(y), jnp.array(mask), b,
            jnp.float32(prior_w), jnp.float32(1.0), block_n=16,
        )
        return v

    _, g = model.logistic_logp_grad(
        jnp.array(x), jnp.array(y), jnp.array(mask), jnp.array(beta),
        jnp.float32(prior_w), jnp.float32(1.0), block_n=16,
    )
    g_fd = _finite_diff_grad(lp, beta)
    np.testing.assert_allclose(np.asarray(g), g_fd, atol=5e-2, rtol=5e-2)


def test_poisson_gamma_grad_matches_finite_diff():
    rng = np.random.default_rng(11)
    n = 64
    ts = np.ones(n, F32)
    xs = rng.poisson(3.0, size=n).astype(F32)
    mask = np.ones(n, F32)
    theta = np.array([0.3, -0.2], F32)
    args = (jnp.array(xs), jnp.array(ts), jnp.array(mask))
    scal = (jnp.float32(0.1), jnp.float32(1.0),
            jnp.float32(2.0), jnp.float32(1.0))

    def lp(th):
        v, _ = model.poisson_gamma_logp_grad(*args, th, *scal)
        return v

    _, g = model.poisson_gamma_logp_grad(*args, jnp.array(theta), *scal)
    g_fd = _finite_diff_grad(lp, theta)
    np.testing.assert_allclose(np.asarray(g), g_fd, atol=5e-2, rtol=5e-2)


def test_gaussian_logp_matches_sum_of_logpdfs():
    rng = np.random.default_rng(5)
    n, d = 16, 3
    x = rng.normal(size=(n, d)).astype(F32)
    mask = np.ones(n, F32)
    theta = rng.normal(size=d).astype(F32)
    lp, g = model.gaussian_logp_grad(
        jnp.array(x), jnp.array(mask), jnp.array(theta),
        jnp.float32(2.0), jnp.float32(0.0), jnp.float32(1.0),
    )
    # prior_w = 0 -> pure likelihood; compare against scipy-style manual sum.
    resid = x - theta
    expected = -0.5 * 2.0 * np.sum(resid ** 2) \
        + 0.5 * n * d * (np.log(2.0) - np.log(2 * np.pi))
    assert abs(float(lp) - expected) < 1e-2
    np.testing.assert_allclose(
        np.asarray(g), 2.0 * resid.sum(axis=0), atol=1e-3, rtol=1e-4
    )


def test_gmm_prior_weighting_scales_prior_only():
    """logp(prior_w=1) - logp(prior_w=0) == full prior log-density."""
    rng = np.random.default_rng(13)
    n, k, dim = 16, 3, 2
    x = rng.normal(size=(n, dim)).astype(F32)
    mask = np.ones(n, F32)
    theta = rng.normal(size=k * dim).astype(F32)
    logw = np.log(np.ones(k, F32) / k)
    common = (jnp.array(x), jnp.array(mask), jnp.array(theta),
              jnp.array(logw), jnp.float32(1.0))

    def lp(w):
        v, _ = model.gmm_logp_grad(
            *common, jnp.float32(w), jnp.float32(0.5),
            n_comp=k, dim=dim, block_n=16,
        )
        return float(v)

    d_full = theta.shape[0]
    prior = -0.5 * 0.5 * np.sum(theta ** 2) \
        + 0.5 * d_full * (np.log(0.5) - np.log(2 * np.pi))
    assert abs((lp(1.0) - lp(0.0)) - prior) < 1e-3
    # And half-weight prior is exactly half of the full prior term.
    assert abs((lp(0.5) - lp(0.0)) - 0.5 * prior) < 1e-3


# ---------------------------------------------------------------------------
# Leapfrog physics
# ---------------------------------------------------------------------------


def _quad_lpg(prec):
    def lpg(th):
        return -0.5 * prec * jnp.sum(th * th), -prec * th
    return lpg


def test_leapfrog_conserves_energy_small_eps():
    lpg = _quad_lpg(1.0)
    theta = jnp.array([1.0, -0.5], jnp.float32)
    p = jnp.array([0.3, 0.7], jnp.float32)
    th_f, p_f, lp_f, _, lp_0 = model.leapfrog(
        lpg, theta, p, jnp.float32(0.01), 100
    )
    h0 = -float(lp_0) + 0.5 * float(jnp.sum(p * p))
    h1 = -float(lp_f) + 0.5 * float(jnp.sum(p_f * p_f))
    assert abs(h1 - h0) < 1e-4


def test_leapfrog_is_reversible():
    lpg = _quad_lpg(2.0)
    theta = jnp.array([0.8, -1.2, 0.1], jnp.float32)
    p = jnp.array([-0.4, 0.2, 0.9], jnp.float32)
    eps = jnp.float32(0.05)
    th_f, p_f, *_ = model.leapfrog(lpg, theta, p, eps, 20)
    # Flip momentum and integrate back.
    th_b, p_b, *_ = model.leapfrog(lpg, th_f, -p_f, eps, 20)
    np.testing.assert_allclose(np.asarray(th_b), np.asarray(theta), atol=1e-4)
    np.testing.assert_allclose(np.asarray(-p_b), np.asarray(p), atol=1e-4)


def test_leapfrog_exact_harmonic_period():
    """For U = theta^2/2, leapfrog with tiny eps tracks the exact rotation."""
    lpg = _quad_lpg(1.0)
    theta = jnp.array([1.0], jnp.float32)
    p = jnp.array([0.0], jnp.float32)
    # Integrate for t = pi/2: (theta, p) rotates to (0, -1).
    n, eps = 1571, 1e-3
    th_f, p_f, *_ = model.leapfrog(lpg, theta, p, jnp.float32(eps), n)
    assert abs(float(th_f[0]) - np.cos(n * eps)) < 1e-3
    assert abs(float(p_f[0]) + np.sin(n * eps)) < 1e-3


def test_hmc_trajectory_returns_initial_logp():
    rng = np.random.default_rng(21)
    n, d = 32, 4
    x = rng.normal(size=(n, d)).astype(F32)
    mask = np.ones(n, F32)
    theta = rng.normal(size=d).astype(F32)
    p = rng.normal(size=d).astype(F32)
    out = model.gaussian_hmc(
        jnp.array(x), jnp.array(mask), jnp.array(theta), jnp.array(p),
        jnp.float32(0.01), jnp.float32(1.0), jnp.float32(0.1),
        jnp.float32(1.0), n_steps=5,
    )
    lp0_direct, _ = model.gaussian_logp_grad(
        jnp.array(x), jnp.array(mask), jnp.array(theta),
        jnp.float32(1.0), jnp.float32(0.1), jnp.float32(1.0),
    )
    assert abs(float(out[4]) - float(lp0_direct)) < 1e-3
