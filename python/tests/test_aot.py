"""AOT registry sanity: manifests are self-consistent and small entries lower."""

import json
import os
import tempfile

import pytest

from compile import aot


def test_registry_names_unique_and_cover_all_models():
    entries = aot.registry()
    names = [e["name"] for e in entries]
    assert len(names) == len(set(names))
    models = {e["model"] for e in entries}
    assert models == {"logistic", "gmm", "poisson_gamma", "gaussian"}
    kinds = {e["kind"] for e in entries}
    assert kinds == {"logp_grad", "hmc"}


def test_registry_specs_consistent():
    for e in aot.registry():
        for s in e["inputs"] + e["outputs"]:
            assert s["dtype"] == "f32"
            assert all(isinstance(x, int) and x > 0 for x in s["shape"])
        in_names = [s["name"] for s in e["inputs"]]
        assert len(in_names) == len(set(in_names))
        if e["kind"] == "hmc":
            out_names = [s["name"] for s in e["outputs"]]
            assert out_names == [
                "theta_out", "p_out", "logp_out", "grad_out", "logp_in"
            ]
            assert "eps" in in_names
        else:
            assert [s["name"] for s in e["outputs"]] == ["logp", "grad"]
        # theta in/out dims agree.
        theta = next(s for s in e["inputs"] if s["name"] == "theta")
        out0 = e["outputs"][0 if e["kind"] == "hmc" else 1]
        grad = e["outputs"][1 if e["kind"] == "logp_grad" else 3]
        assert grad["shape"] == theta["shape"]
        if e["kind"] == "hmc":
            assert out0["shape"] == theta["shape"]


@pytest.mark.parametrize("only", ["gauss_lpg_n512_d2", "pg_lpg_n5120"])
def test_lower_entry_produces_hlo_text(only):
    entry = next(e for e in aot.registry() if e["name"] == only)
    with tempfile.TemporaryDirectory() as td:
        meta, nchars = aot.lower_entry(entry, td)
        assert nchars > 100
        path = os.path.join(td, meta["file"])
        with open(path) as f:
            head = f.read(200)
        assert head.startswith("HloModule")
        # Entry layout mentions the right number of parameters.
        assert meta["inputs"] == entry["inputs"]


def test_manifest_roundtrips_json():
    entry = next(e for e in aot.registry() if e["name"] == "gauss_lpg_n512_d2")
    with tempfile.TemporaryDirectory() as td:
        meta, _ = aot.lower_entry(entry, td)
        blob = json.dumps([meta])
        back = json.loads(blob)
        assert back[0]["name"] == "gauss_lpg_n512_d2"
        assert back[0]["params"]["d"] == 2
