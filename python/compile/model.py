"""L2: subposterior log-density graphs + fused HMC leapfrog trajectories.

Each model exposes

  logp_grad(<data...>, theta, <scalars...>) -> (logp, grad)

where `logp` is the *subposterior* log-density of Eq. 2.1 in the paper:

    log p_m(theta) = (1/M) * log p(theta) + log p(x^{n_m} | theta)

with the prior weight 1/M passed in as the runtime scalar `prior_w`, so a
single artifact serves any number of machines M (and `prior_w = 1.0`
recovers the full-data posterior used by the regularChain baseline).

Each model also exposes a fused `hmc(...)` trajectory: L leapfrog steps
rolled into one lax.scan so the rust worker advances a whole HMC proposal
with a single PJRT call instead of 2L+1 (this is the L2 perf optimization
recorded in EXPERIMENTS.md section Perf).

The likelihood hot-spots call the L1 Pallas kernels (kernels.logistic,
kernels.gmm) so they lower into the same HLO module.
"""

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import gmm as gmm_kernel
from .kernels import logistic as logistic_kernel

# ---------------------------------------------------------------------------
# Generic fused leapfrog
# ---------------------------------------------------------------------------


def leapfrog(lpg_fn, theta, p, eps, n_steps):
    """L leapfrog steps of HMC in one lax.scan.

    Args:
      lpg_fn: theta -> (logp, grad) closure (the subposterior).
      theta: (d,) position. p: (d,) momentum. eps: f32[] step size.
      n_steps: static trajectory length L.

    Returns:
      (theta_L, p_L, logp_L, grad_L, logp_0): final state plus the initial
      log-density so the rust caller can form the MH ratio without a second
      round-trip.
    """
    lp0, g0 = lpg_fn(theta)

    def step(carry, _):
        th, mom, _lp, g = carry
        mom_half = mom + 0.5 * eps * g
        th_new = th + eps * mom_half
        lp_new, g_new = lpg_fn(th_new)
        mom_new = mom_half + 0.5 * eps * g_new
        return (th_new, mom_new, lp_new, g_new), None

    (theta_f, p_f, lp_f, g_f), _ = lax.scan(
        step, (theta, p, lp0, g0), None, length=n_steps
    )
    return theta_f, p_f, lp_f, g_f, lp0


def _gauss_prior(theta, prior_w, prior_prec):
    """Powered isotropic Gaussian prior: prior_w * log N(theta | 0, I/prec).

    Includes the normalizing constant so the rust native backend and the
    artifact agree on absolute values (parity tests), not just deltas.
    """
    d = theta.shape[0]
    lp = -0.5 * prior_prec * jnp.sum(theta * theta) + 0.5 * d * (
        jnp.log(prior_prec) - jnp.log(2.0 * jnp.pi)
    )
    grad = -prior_prec * theta
    return prior_w * lp, prior_w * grad


# ---------------------------------------------------------------------------
# Logistic regression (paper section 8.1)
# ---------------------------------------------------------------------------


def logistic_logp_grad(x, y, mask, beta, prior_w, prior_prec, *, block_n):
    ll, gl = logistic_kernel.loglik_grad(x, y, mask, beta, block_n=block_n)
    lp_pr, g_pr = _gauss_prior(beta, prior_w, prior_prec)
    return ll + lp_pr, gl + g_pr


def logistic_hmc(x, y, mask, theta, p, eps, prior_w, prior_prec,
                 *, n_steps, block_n):
    def lpg(th):
        return logistic_logp_grad(
            x, y, mask, th, prior_w, prior_prec, block_n=block_n
        )

    return leapfrog(lpg, theta, p, eps, n_steps)


# ---------------------------------------------------------------------------
# Gaussian mixture over component means (paper section 8.2)
# ---------------------------------------------------------------------------


def gmm_logp_grad(x, mask, theta, logw, inv_var, prior_w, prior_prec,
                  *, n_comp, dim, block_n):
    mu = theta.reshape(n_comp, dim)
    ll, gl = gmm_kernel.loglik_grad(
        x, mask, mu, logw, jnp.reshape(inv_var, (1,)), block_n=block_n
    )
    lp_pr, g_pr = _gauss_prior(theta, prior_w, prior_prec)
    return ll + lp_pr, gl.reshape(-1) + g_pr


def gmm_hmc(x, mask, theta, p, eps, logw, inv_var, prior_w, prior_prec,
            *, n_comp, dim, n_steps, block_n):
    def lpg(th):
        return gmm_logp_grad(
            x, mask, th, logw, inv_var, prior_w, prior_prec,
            n_comp=n_comp, dim=dim, block_n=block_n,
        )

    return leapfrog(lpg, theta, p, eps, n_steps)


# ---------------------------------------------------------------------------
# Poisson-gamma hierarchical model (paper section 8.3)
#
# a ~ Exp(lam), b ~ Gamma(alpha, beta_p), q_i ~ Gamma(a, b),
# x_i ~ Poisson(q_i t_i). The q_i are marginalized analytically:
#   p(x_i | a, b) = C(x_i + a - 1, x_i) (b/(b+t_i))^a (t_i/(b+t_i))^{x_i}
# (negative binomial), so theta = (log a, log b) in R^2 -- an unconstrained
# space as the paper's method requires. The log transform contributes the
# Jacobian log a + log b to the (powered) prior.
# ---------------------------------------------------------------------------


def _pg_logpost(theta, xs, ts, mask, prior_w, lam, alpha, beta_p):
    log_a, log_b = theta[0], theta[1]
    a = jnp.exp(log_a)
    b = jnp.exp(log_b)
    gammaln = jax.scipy.special.gammaln
    # Negative-binomial marginal likelihood per observation.
    ll_i = (
        gammaln(xs + a)
        - gammaln(a)
        - gammaln(xs + 1.0)
        + a * (jnp.log(b) - jnp.log(b + ts))
        + xs * (jnp.log(ts) - jnp.log(b + ts))
    )
    ll = jnp.sum(mask * ll_i)
    # Powered prior + Jacobian of the log transform.
    lp_a = jnp.log(lam) - lam * a
    lp_b = alpha * jnp.log(beta_p) - gammaln(alpha) \
        + (alpha - 1.0) * jnp.log(b) - beta_p * b
    return ll + prior_w * (lp_a + lp_b) + log_a + log_b


def poisson_gamma_logp_grad(xs, ts, mask, theta, prior_w, lam, alpha, beta_p):
    lp, grad = jax.value_and_grad(_pg_logpost)(
        theta, xs, ts, mask, prior_w, lam, alpha, beta_p
    )
    return lp, grad


def poisson_gamma_hmc(xs, ts, mask, theta, p, eps, prior_w, lam, alpha,
                      beta_p, *, n_steps):
    def lpg(th):
        return poisson_gamma_logp_grad(
            xs, ts, mask, th, prior_w, lam, alpha, beta_p
        )

    return leapfrog(lpg, theta, p, eps, n_steps)


# ---------------------------------------------------------------------------
# Conjugate Gaussian (exactness anchor; DESIGN.md section 6)
#
# x_i ~ N(theta, I/lik_prec), theta ~ N(0, I/prior_prec). The subposterior
# product has a closed form, so the rust side can verify the combination
# algorithms against ground truth exactly.
# ---------------------------------------------------------------------------


def gaussian_logp_grad(x, mask, theta, lik_prec, prior_w, prior_prec):
    d = theta.shape[0]
    resid = x - theta[None, :]
    ll = -0.5 * lik_prec * jnp.sum(mask[:, None] * resid * resid) \
        + 0.5 * d * jnp.sum(mask) * (jnp.log(lik_prec) - jnp.log(2.0 * jnp.pi))
    gl = lik_prec * jnp.sum(mask[:, None] * resid, axis=0)
    lp_pr, g_pr = _gauss_prior(theta, prior_w, prior_prec)
    return ll + lp_pr, gl + g_pr


def gaussian_hmc(x, mask, theta, p, eps, lik_prec, prior_w, prior_prec,
                 *, n_steps):
    def lpg(th):
        return gaussian_logp_grad(x, mask, th, lik_prec, prior_w, prior_prec)

    return leapfrog(lpg, theta, p, eps, n_steps)
