"""L1 Pallas kernel: fused logistic-regression log-likelihood + gradient.

This is the per-sample O(n_shard * d) hot-spot of the embarrassingly
parallel MCMC worker: every HMC leapfrog step evaluates

    loglik(beta) = sum_i mask_i * ( y_i * z_i - softplus(z_i) ),  z = X @ beta
    grad(beta)   = X^T ( mask * (y - sigmoid(z)) )

in one pass over the data shard. The kernel tiles X into (BLOCK_N, d)
VMEM blocks via BlockSpec and accumulates the scalar log-likelihood and
the d-dim gradient across the grid in the output refs (revisited on every
grid step, i.e. VMEM-resident accumulators).

TPU adaptation notes (DESIGN.md section Hardware-Adaptation): the X @ beta
contraction and the X^T r back-contraction are MXU work; padded rows are
masked instead of branching; accumulators stay f32. On this image the
kernel runs under interpret=True (CPU PJRT cannot execute Mosaic
custom-calls), so correctness is validated against kernels.ref and
performance is argued structurally (VMEM footprint, single pass).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default row-block. (512 x 64) f32 = 128 KiB of VMEM for the X tile,
# well under the ~16 MiB/core budget, leaving room for double buffering.
DEFAULT_BLOCK_N = 512


def _loglik_grad_kernel(x_ref, y_ref, mask_ref, beta_ref, ll_ref, grad_ref):
    """One grid step: accumulate loglik + grad contributions of a row block."""
    i = pl.program_id(0)

    x = x_ref[...].astype(jnp.float32)        # (bn, d)
    y = y_ref[...].astype(jnp.float32)        # (bn,)
    mask = mask_ref[...].astype(jnp.float32)  # (bn,)
    beta = beta_ref[...].astype(jnp.float32)  # (d,)

    z = x @ beta                               # MXU contraction, (bn,)
    # Numerically stable softplus: log(1 + e^z) = max(z, 0) + log1p(e^{-|z|}).
    softplus = jnp.maximum(z, 0.0) + jnp.log1p(jnp.exp(-jnp.abs(z)))
    ll_blk = jnp.sum(mask * (y * z - softplus))

    resid = mask * (y - jax.nn.sigmoid(z))     # (bn,)
    grad_blk = resid @ x                       # MXU back-contraction, (d,)

    @pl.when(i == 0)
    def _init():
        ll_ref[...] = jnp.zeros_like(ll_ref)
        grad_ref[...] = jnp.zeros_like(grad_ref)

    ll_ref[...] += ll_blk[None]
    grad_ref[...] += grad_blk


def loglik_grad(x, y, mask, beta, *, block_n: int = DEFAULT_BLOCK_N):
    """Fused logistic log-likelihood and gradient over a (padded) shard.

    Args:
      x: (n, d) float32 design matrix; n must be a multiple of block_n
         (callers pad with zero-mask rows — see pad_rows()).
      y: (n,) float32 0/1 labels.
      mask: (n,) float32 validity mask (0.0 for padded rows).
      beta: (d,) float32 parameter.
      block_n: rows per VMEM tile.

    Returns:
      (loglik, grad): f32[] and f32[d].
    """
    n, d = x.shape
    if n % block_n != 0:
        raise ValueError(f"n={n} must be a multiple of block_n={block_n}")
    grid = (n // block_n,)
    ll, grad = pl.pallas_call(
        _loglik_grad_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1,), jnp.float32),
            jax.ShapeDtypeStruct((d,), jnp.float32),
        ],
        interpret=True,
    )(x, y, mask, beta)
    return ll[0], grad


def pad_rows(n: int, block_n: int = DEFAULT_BLOCK_N) -> int:
    """Smallest multiple of block_n that is >= n (and >= block_n)."""
    return max(block_n, ((n + block_n - 1) // block_n) * block_n)


def choose_block_n(n: int, preferred: int = DEFAULT_BLOCK_N) -> int:
    """Pick a row-block size: `preferred` unless the shard is tiny."""
    if n >= preferred:
        return preferred
    # Round tiny shards up to a single block of at least 8 rows.
    b = 8
    while b < n:
        b *= 2
    return b


@functools.partial(jax.jit, static_argnames=("block_n",))
def loglik_grad_jit(x, y, mask, beta, block_n: int = DEFAULT_BLOCK_N):
    return loglik_grad(x, y, mask, beta, block_n=block_n)
