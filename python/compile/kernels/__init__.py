"""L1: Pallas kernels for the paper's compute hot-spots.

- logistic: fused logistic-regression log-likelihood + gradient (Fig. 1-3).
- gmm: Gaussian-mixture log-likelihood + gradient over component means
  (Fig. 4-5 left).
- ref: pure-jnp oracles used by the pytest/hypothesis correctness sweeps.
"""

from . import gmm, logistic, ref  # noqa: F401
