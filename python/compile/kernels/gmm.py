"""L1 Pallas kernel: Gaussian-mixture log-likelihood + gradient w.r.t. means.

Model (paper section 8.2): x_i ~ sum_k pi_k N(mu_k, sigma^2 I_dim), with
known weights pi_k and known isotropic variance sigma^2; the posterior is
over the K component means (theta = flattened (K, dim) matrix), and is
multimodal under label permutation.

Per data block the kernel computes, for every point i and component k,

    z_ik = log pi_k - ||x_i - mu_k||^2 / (2 sigma^2) - dim/2 log(2 pi sigma^2)
    ll_i = logsumexp_k z_ik
    r_ik = exp(z_ik - ll_i)                  (responsibilities)
    d ll / d mu_k = sum_i mask_i r_ik (x_i - mu_k) / sigma^2

and accumulates sum_i mask_i ll_i and the (K, dim) gradient across the
grid. The pairwise distance expansion ||x - mu||^2 =
|x|^2 - 2 x @ mu^T + |mu|^2 keeps the inner contraction on the MXU.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 512


def _gmm_kernel(x_ref, mask_ref, mu_ref, logw_ref, inv_var_ref,
                ll_ref, grad_ref):
    i = pl.program_id(0)

    x = x_ref[...].astype(jnp.float32)          # (bn, dim)
    mask = mask_ref[...].astype(jnp.float32)    # (bn,)
    mu = mu_ref[...].astype(jnp.float32)        # (K, dim)
    logw = logw_ref[...].astype(jnp.float32)    # (K,)
    inv_var = inv_var_ref[0]                    # scalar 1/sigma^2

    dim = x.shape[1]
    log_norm = 0.5 * dim * (jnp.log(2.0 * jnp.pi) - jnp.log(inv_var))

    # Squared distances via MXU-friendly expansion.
    x2 = jnp.sum(x * x, axis=1, keepdims=True)          # (bn, 1)
    m2 = jnp.sum(mu * mu, axis=1)[None, :]              # (1, K)
    cross = x @ mu.T                                    # (bn, K) on MXU
    sq = x2 - 2.0 * cross + m2                          # (bn, K)

    z = logw[None, :] - 0.5 * inv_var * sq - log_norm   # (bn, K)
    zmax = jnp.max(z, axis=1, keepdims=True)
    ez = jnp.exp(z - zmax)
    sez = jnp.sum(ez, axis=1, keepdims=True)
    ll_i = (zmax[:, 0] + jnp.log(sez[:, 0]))            # (bn,)
    ll_blk = jnp.sum(mask * ll_i)

    r = ez / sez                                        # responsibilities
    rm = r * mask[:, None]                              # (bn, K)
    # grad_k = inv_var * ( sum_i rm_ik x_i - (sum_i rm_ik) mu_k )
    rx = rm.T @ x                                       # (K, dim) on MXU
    rsum = jnp.sum(rm, axis=0)                          # (K,)
    grad_blk = inv_var * (rx - rsum[:, None] * mu)      # (K, dim)

    @pl.when(i == 0)
    def _init():
        ll_ref[...] = jnp.zeros_like(ll_ref)
        grad_ref[...] = jnp.zeros_like(grad_ref)

    ll_ref[...] += ll_blk[None]
    grad_ref[...] += grad_blk


def loglik_grad(x, mask, mu, logw, inv_var, *, block_n: int = DEFAULT_BLOCK_N):
    """GMM log-likelihood and gradient w.r.t. component means.

    Args:
      x: (n, dim) data shard (n a multiple of block_n; pad with mask=0).
      mask: (n,) validity mask.
      mu: (K, dim) component means.
      logw: (K,) log mixture weights.
      inv_var: f32[1] -- 1 / sigma^2.

    Returns:
      (loglik, grad): f32[] and f32[K, dim].
    """
    n, dim = x.shape
    k = mu.shape[0]
    if n % block_n != 0:
        raise ValueError(f"n={n} must be a multiple of block_n={block_n}")
    grid = (n // block_n,)
    ll, grad = pl.pallas_call(
        _gmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, dim), lambda i: (i, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((k, dim), lambda i: (0, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((k, dim), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1,), jnp.float32),
            jax.ShapeDtypeStruct((k, dim), jnp.float32),
        ],
        interpret=True,
    )(x, mask, mu, logw, inv_var)
    return ll[0], grad
