"""Pure-jnp oracles for the Pallas kernels.

These are the CORE correctness anchors: every kernel in this package must
agree with its oracle to float32 tolerance over randomized shapes/values
(python/tests/test_kernel.py runs the hypothesis sweeps).
"""

import jax
import jax.numpy as jnp


def logistic_loglik_grad(x, y, mask, beta):
    """Reference for kernels.logistic.loglik_grad (masked, stable)."""
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    mask = mask.astype(jnp.float32)
    beta = beta.astype(jnp.float32)
    z = x @ beta
    softplus = jnp.maximum(z, 0.0) + jnp.log1p(jnp.exp(-jnp.abs(z)))
    ll = jnp.sum(mask * (y * z - softplus))
    grad = (mask * (y - jax.nn.sigmoid(z))) @ x
    return ll, grad


def gmm_loglik(x, mask, mu, logw, inv_var):
    """Reference GMM log-likelihood (value only)."""
    x = x.astype(jnp.float32)
    mu = mu.astype(jnp.float32)
    dim = x.shape[1]
    inv_var = jnp.asarray(inv_var, jnp.float32).reshape(())
    log_norm = 0.5 * dim * (jnp.log(2.0 * jnp.pi) - jnp.log(inv_var))
    sq = jnp.sum((x[:, None, :] - mu[None, :, :]) ** 2, axis=-1)  # (n, K)
    z = logw[None, :] - 0.5 * inv_var * sq - log_norm
    ll_i = jax.scipy.special.logsumexp(z, axis=1)
    return jnp.sum(mask * ll_i)


def gmm_loglik_grad(x, mask, mu, logw, inv_var):
    """Reference for kernels.gmm.loglik_grad: value + autodiff gradient."""
    ll, grad = jax.value_and_grad(gmm_loglik, argnums=2)(
        x, mask, mu, logw, inv_var
    )
    return ll, grad
