"""AOT compiler: lower every (model, shape) config to HLO *text* + manifest.

HLO text (NOT lowered.compiler_ir(...).serialize()) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which
the rust `xla` crate's xla_extension 0.5.1 rejects (`proto.id() <=
INT_MAX`); the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/gen_hlo.py.

Usage (from python/):  python -m compile.aot --out-dir ../artifacts [--only NAME]

Outputs:
  artifacts/<name>.hlo.txt   one module per artifact
  artifacts/manifest.json    input/output specs + baked constants, read by
                             rust/src/runtime/artifact.rs
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import logistic as logistic_kernel

F32 = "f32"


def spec(name, shape, dtype=F32):
    return {"name": name, "shape": list(shape), "dtype": dtype}


def _shape_structs(in_specs):
    dt = {F32: jnp.float32}
    return [
        jax.ShapeDtypeStruct(tuple(s["shape"]), dt[s["dtype"]])
        for s in in_specs
    ]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ---------------------------------------------------------------------------
# Artifact registry
# ---------------------------------------------------------------------------


def _logistic_entries(n_pad, d, n_steps):
    """(lpg, hmc) artifact entries for a padded logistic shard of n_pad rows."""
    block_n = logistic_kernel.choose_block_n(n_pad)
    data = [spec("x", (n_pad, d)), spec("y", (n_pad,)), spec("mask", (n_pad,))]
    scalars = [spec("prior_w", ()), spec("prior_prec", ())]
    lpg = {
        "name": f"logistic_lpg_n{n_pad}_d{d}",
        "kind": "logp_grad",
        "model": "logistic",
        "params": {"n": n_pad, "d": d, "block_n": block_n},
        "inputs": data + [spec("theta", (d,))] + scalars,
        "outputs": [spec("logp", ()), spec("grad", (d,))],
        "fn": functools.partial(model.logistic_logp_grad, block_n=block_n),
    }
    hmc = {
        "name": f"logistic_hmc_n{n_pad}_d{d}_L{n_steps}",
        "kind": "hmc",
        "model": "logistic",
        "params": {"n": n_pad, "d": d, "block_n": block_n, "n_steps": n_steps},
        "inputs": data
        + [spec("theta", (d,)), spec("p", (d,)), spec("eps", ())]
        + scalars,
        "outputs": [
            spec("theta_out", (d,)),
            spec("p_out", (d,)),
            spec("logp_out", ()),
            spec("grad_out", (d,)),
            spec("logp_in", ()),
        ],
        "fn": functools.partial(
            model.logistic_hmc, n_steps=n_steps, block_n=block_n
        ),
    }
    return [lpg, hmc]


def _gmm_entries(n_pad, n_comp, dim, n_steps):
    block_n = logistic_kernel.choose_block_n(n_pad)
    td = n_comp * dim
    data = [spec("x", (n_pad, dim)), spec("mask", (n_pad,))]
    tail = [
        spec("logw", (n_comp,)),
        spec("inv_var", ()),
        spec("prior_w", ()),
        spec("prior_prec", ()),
    ]
    kw = dict(n_comp=n_comp, dim=dim, block_n=block_n)
    lpg = {
        "name": f"gmm_lpg_n{n_pad}_k{n_comp}_dim{dim}",
        "kind": "logp_grad",
        "model": "gmm",
        "params": {"n": n_pad, "k": n_comp, "dim": dim, "block_n": block_n},
        "inputs": data + [spec("theta", (td,))] + tail,
        "outputs": [spec("logp", ()), spec("grad", (td,))],
        "fn": functools.partial(model.gmm_logp_grad, **kw),
    }
    hmc = {
        "name": f"gmm_hmc_n{n_pad}_k{n_comp}_dim{dim}_L{n_steps}",
        "kind": "hmc",
        "model": "gmm",
        "params": {
            "n": n_pad, "k": n_comp, "dim": dim,
            "block_n": block_n, "n_steps": n_steps,
        },
        "inputs": data
        + [spec("theta", (td,)), spec("p", (td,)), spec("eps", ())]
        + tail,
        "outputs": [
            spec("theta_out", (td,)),
            spec("p_out", (td,)),
            spec("logp_out", ()),
            spec("grad_out", (td,)),
            spec("logp_in", ()),
        ],
        "fn": functools.partial(model.gmm_hmc, n_steps=n_steps, **kw),
    }
    return [lpg, hmc]


def _pg_entries(n_pad, n_steps):
    data = [spec("xs", (n_pad,)), spec("ts", (n_pad,)), spec("mask", (n_pad,))]
    scalars = [
        spec("prior_w", ()),
        spec("lam", ()),
        spec("alpha", ()),
        spec("beta_p", ()),
    ]
    lpg = {
        "name": f"pg_lpg_n{n_pad}",
        "kind": "logp_grad",
        "model": "poisson_gamma",
        "params": {"n": n_pad, "d": 2},
        "inputs": data + [spec("theta", (2,))] + scalars,
        "outputs": [spec("logp", ()), spec("grad", (2,))],
        "fn": model.poisson_gamma_logp_grad,
    }
    hmc = {
        "name": f"pg_hmc_n{n_pad}_L{n_steps}",
        "kind": "hmc",
        "model": "poisson_gamma",
        "params": {"n": n_pad, "d": 2, "n_steps": n_steps},
        "inputs": data
        + [spec("theta", (2,)), spec("p", (2,)), spec("eps", ())]
        + scalars,
        "outputs": [
            spec("theta_out", (2,)),
            spec("p_out", (2,)),
            spec("logp_out", ()),
            spec("grad_out", (2,)),
            spec("logp_in", ()),
        ],
        "fn": functools.partial(model.poisson_gamma_hmc, n_steps=n_steps),
    }
    return [lpg, hmc]


def _gaussian_entries(n_pad, d, n_steps):
    data = [spec("x", (n_pad, d)), spec("mask", (n_pad,))]
    scalars = [
        spec("lik_prec", ()),
        spec("prior_w", ()),
        spec("prior_prec", ()),
    ]
    lpg = {
        "name": f"gauss_lpg_n{n_pad}_d{d}",
        "kind": "logp_grad",
        "model": "gaussian",
        "params": {"n": n_pad, "d": d},
        "inputs": data + [spec("theta", (d,))] + scalars,
        "outputs": [spec("logp", ()), spec("grad", (d,))],
        "fn": model.gaussian_logp_grad,
    }
    hmc = {
        "name": f"gauss_hmc_n{n_pad}_d{d}_L{n_steps}",
        "kind": "hmc",
        "model": "gaussian",
        "params": {"n": n_pad, "d": d, "n_steps": n_steps},
        "inputs": data
        + [spec("theta", (d,)), spec("p", (d,)), spec("eps", ())]
        + scalars,
        "outputs": [
            spec("theta_out", (d,)),
            spec("p_out", (d,)),
            spec("logp_out", ()),
            spec("grad_out", (d,)),
            spec("logp_in", ()),
        ],
        "fn": functools.partial(model.gaussian_hmc, n_steps=n_steps),
    }
    return [lpg, hmc]


def registry():
    """Artifact set covering the test suite and every paper experiment."""
    entries = []
    # Small shapes: rust unit/integration tests + quickstart example.
    entries += _gaussian_entries(n_pad=512, d=2, n_steps=10)
    entries += _logistic_entries(n_pad=512, d=8, n_steps=10)
    # Fig. 1/2: synthetic logistic N=50k d=50; shards for M=10 and M=20.
    entries += _logistic_entries(n_pad=5120, d=50, n_steps=10)
    entries += _logistic_entries(n_pad=2560, d=50, n_steps=10)
    # Fig. 4/5-left: GMM K=10 in 2-d, M=10 shards of 5k.
    entries += _gmm_entries(n_pad=5120, n_comp=10, dim=2, n_steps=10)
    # Fig. 5-right: Poisson-gamma, M=10 shards of 5k.
    entries += _pg_entries(n_pad=5120, n_steps=10)
    return entries


def lower_entry(entry, out_dir):
    structs = _shape_structs(entry["inputs"])
    lowered = jax.jit(entry["fn"]).lower(*structs)
    text = to_hlo_text(lowered)
    fname = f"{entry['name']}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    meta = {k: entry[k] for k in
            ("name", "kind", "model", "params", "inputs", "outputs")}
    meta["file"] = fname
    return meta, len(text)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="substring filter on artifact names")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = []
    for entry in registry():
        if args.only and args.only not in entry["name"]:
            continue
        meta, nchars = lower_entry(entry, args.out_dir)
        manifest.append(meta)
        print(f"  lowered {entry['name']} ({nchars} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {len(manifest)} artifacts to {args.out_dir}")


if __name__ == "__main__":
    main()
